//! MOT1/USE1 — the §2 motivation: ML_INFN's VM-per-group provisioning
//! vs the AI_INFN platform, replayed on the same user trace.
//!
//! VM model (ML_INFN): each research group gets a long-lived VM with
//! pinned GPUs; GPUs idle whenever the group is offline; every software
//! change is an admin ticket; stateful VMs make eviction dangerous.
//!
//! Platform model (AI_INFN): per-session scheduling from the shared
//! farm; idle sessions culled; opportunistic batch backfills idle GPUs.
//!
//! Metrics: GPU allocation efficiency (useful-hours / wall-hours per
//! GPU), admin interventions, and "dangerous evictions" (forced
//! teardown of stateful deployments).

use std::collections::BTreeMap;

use crate::util::csv::Table;
use crate::util::rng::Rng;
use crate::workload::Population;

#[derive(Clone, Debug, Default)]
pub struct ModelMetrics {
    pub gpu_busy_hours: f64,
    pub gpu_wall_hours: f64,
    pub admin_ops: u64,
    pub dangerous_evictions: u64,
    pub served_sessions: u64,
    pub denied_sessions: u64,
}

impl ModelMetrics {
    pub fn utilisation(&self) -> f64 {
        if self.gpu_wall_hours == 0.0 {
            0.0
        } else {
            self.gpu_busy_hours / self.gpu_wall_hours
        }
    }
}

const TOTAL_GPUS: u32 = 20;
const HOURS_PER_DAY: f64 = 24.0;

/// Replay `days` working days under the ML_INFN VM model.
pub fn replay_vm_model(pop: &Population, days: usize, seed: u64) -> ModelMetrics {
    let mut rng = Rng::new(seed);
    let mut m = ModelMetrics::default();

    // Partition GPUs among activities by user share (static pinning).
    let mut activity_users: BTreeMap<&str, usize> = BTreeMap::new();
    for u in &pop.users {
        *activity_users.entry(u.activity.as_str()).or_default() += 1;
    }
    let total_users: usize = activity_users.values().sum();
    let mut gpus_of: BTreeMap<&str, u32> = BTreeMap::new();
    let mut assigned = 0u32;
    for (act, n) in &activity_users {
        let share = ((*n as f64 / total_users as f64) * TOTAL_GPUS as f64)
            .round() as u32;
        let share = share.min(TOTAL_GPUS - assigned).max(if assigned < TOTAL_GPUS { 1 } else { 0 });
        gpus_of.insert(act, share);
        assigned += share;
        if assigned >= TOTAL_GPUS {
            break;
        }
    }

    for _day in 0..days {
        let cohort = pop.daily_cohort(&mut rng);
        // Wall hours: every pinned GPU exists all day.
        m.gpu_wall_hours += TOTAL_GPUS as f64 * HOURS_PER_DAY;
        // Busy hours: a group's VM GPUs are busy while members work.
        let mut hours_of: BTreeMap<&str, f64> = BTreeMap::new();
        for u in &cohort {
            let h = (u.session_mean_s / 3600.0).min(12.0);
            let e = hours_of.entry(u.activity.as_str()).or_default();
            *e = (*e + h).min(HOURS_PER_DAY);
            m.served_sessions += 1;
        }
        for (act, hours) in hours_of {
            let gpus = gpus_of.get(act).copied().unwrap_or(0);
            m.gpu_busy_hours += gpus as f64 * hours;
        }
        // Admin burden: §2 — software-stack tickets and user support on
        // a multi-user VM. ~1 ticket per active group per week.
        m.admin_ops += (pop.n_activities() as f64 / 7.0).round() as u64;
        // Dangerous evictions: reassigning a stateful VM when a new
        // group needs GPUs (a few per month at 2023 load).
        if rng.bool(0.1) {
            m.dangerous_evictions += 1;
        }
    }
    m
}

/// Replay the same trace under the AI_INFN platform model.
pub fn replay_platform_model(
    pop: &Population,
    days: usize,
    seed: u64,
) -> ModelMetrics {
    let mut rng = Rng::new(seed);
    let mut m = ModelMetrics::default();

    for _day in 0..days {
        let cohort = pop.daily_cohort(&mut rng);
        m.gpu_wall_hours += TOTAL_GPUS as f64 * HOURS_PER_DAY;
        // Sessions request GPUs only while running; batch backfills the
        // rest (counted as useful at a discount — it is opportunistic
        // work that would otherwise queue).
        let mut interactive_gpu_hours = 0.0;
        let mut requested = 0u32;
        for u in &cohort {
            if u.flavor.is_some() {
                requested += 1;
                if requested <= TOTAL_GPUS {
                    interactive_gpu_hours +=
                        (u.session_mean_s / 3600.0).min(12.0);
                    m.served_sessions += 1;
                } else {
                    m.denied_sessions += 1;
                }
            } else {
                m.served_sessions += 1;
            }
        }
        let idle_gpu_hours =
            TOTAL_GPUS as f64 * HOURS_PER_DAY - interactive_gpu_hours;
        // Opportunistic batch fills ~80% of idle GPU time (Kueue keeps a
        // queue of flash-sim style work; see KUE1 for the mechanism).
        let batch_fill = 0.8 * idle_gpu_hours.max(0.0);
        m.gpu_busy_hours += interactive_gpu_hours + batch_fill;
        // Admin burden: managed environments + self-service spawner —
        // roughly one platform-wide intervention per week.
        if rng.bool(1.0 / 7.0) {
            m.admin_ops += 1;
        }
        // Kueue evictions are safe by design (stateless batch): no
        // dangerous evictions of stateful user deployments.
    }
    m
}

pub fn run_vm_vs_platform(days: usize, seed: u64) -> (ModelMetrics, ModelMetrics, Table) {
    let mut rng = Rng::new(seed);
    let pop = Population::ai_infn(&mut rng);
    let vm = replay_vm_model(&pop, days, seed ^ 1);
    let platform = replay_platform_model(&pop, days, seed ^ 1);

    let mut table = Table::new(&["metric", "ml_infn_vm_model", "ai_infn_platform"]);
    table.push_row(&[
        "gpu_utilisation".into(),
        format!("{:.2}", vm.utilisation()),
        format!("{:.2}", platform.utilisation()),
    ]);
    table.push_row(&[
        "admin_ops".into(),
        vm.admin_ops.to_string(),
        platform.admin_ops.to_string(),
    ]);
    table.push_row(&[
        "dangerous_evictions".into(),
        vm.dangerous_evictions.to_string(),
        platform.dangerous_evictions.to_string(),
    ]);
    table.push_row(&[
        "served_sessions".into(),
        vm.served_sessions.to_string(),
        platform.served_sessions.to_string(),
    ]);
    table.push_row(&[
        "denied_sessions".into(),
        vm.denied_sessions.to_string(),
        platform.denied_sessions.to_string(),
    ]);
    (vm, platform, table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn platform_beats_vm_model_on_motivation_metrics() {
        let (vm, platform, _) = run_vm_vs_platform(60, 42);
        assert!(
            platform.utilisation() > 1.5 * vm.utilisation(),
            "platform {:.2} vs vm {:.2}",
            platform.utilisation(),
            vm.utilisation()
        );
        assert!(platform.admin_ops < vm.admin_ops / 3);
        assert_eq!(platform.dangerous_evictions, 0);
        assert!(vm.dangerous_evictions > 0);
    }

    #[test]
    fn vm_model_utilisation_is_low() {
        // The §2 story: pinned VMs idle most of the time.
        let (vm, _, _) = run_vm_vs_platform(60, 7);
        assert!(
            vm.utilisation() < 0.35,
            "VM-model utilisation {:.2} should be poor",
            vm.utilisation()
        );
    }

    #[test]
    fn deterministic() {
        let (_, _, a) = run_vm_vs_platform(30, 9);
        let (_, _, b) = run_vm_vs_platform(30, 9);
        assert_eq!(a.to_csv(), b.to_csv());
    }
}
