//! SERVING — the inference-serving scenario: one [`InferenceService`]
//! under a diurnal + flash-crowd request trace, its replicas competing
//! with a notebook wave under the cohort quota tree on fractional
//! A100s.
//!
//! Acceptance (the `ainfn fed-stress --serving` gate): at ≥1M requests
//! per simulated peak hour, the queue-latency autoscaler holds the p99
//! SLO through the flash crowd while beating the static-replica
//! baseline (`static_mode`, the degenerate `min == max` spec) on GPU
//! occupancy — and, like every scenario, the time-series and placement
//! CSVs are byte-identical across the {Indexed, LinearScan} ×
//! {Polling, Reactive} mode matrix.
//!
//! The notebook wave lands *mid-flash*, when serving has borrowed the
//! notebooks' idle quota up to the cohort ceiling: the reclaim stage
//! evicts the junior-most replicas (`PreemptReason::ReclaimBorrowed`),
//! the evicted workloads requeue, and the autoscaler keeps counting
//! them live — so the fleet re-fills when the notebooks finish, with
//! no livelock (the regression in `rust/tests/quota_prop.rs`).

use crate::cluster::{
    scaled_farm, GpuModel, PlacementMode, PodSpec, Resources, SliceProfile,
};
use crate::coordinator::{CycleCounts, LoopMode, Platform};
use crate::kueue::{ClusterQueue, QuotaVec};
use crate::offload::VirtualNodeController;
use crate::util::csv::Table;
use crate::workload::serving::{
    BatcherPolicy, InferenceService, SloSpec, TraceSpec, DIURNAL_DEFAULT,
};

use super::fed_stress::placements_table;

#[derive(Clone, Debug)]
pub struct ServingConfig {
    pub seed: u64,
    /// Simulated horizon and sampling cadence, whole seconds (keep both
    /// multiples of the 5 s serving/admission grid).
    pub horizon_s: u64,
    pub sample_every_s: u64,
    /// Trace shape: diurnal base plus one flash-crowd window.
    pub base_rps: u64,
    pub flash_at_s: u64,
    pub flash_len_s: u64,
    pub flash_rps: u64,
    pub slo_p99_us: u64,
    pub max_replicas: u64,
    /// Static-replica baseline: pin `min == max == static_replicas`
    /// so only the autoscaler's repair rule ever fires.
    pub static_mode: bool,
    pub static_replicas: u64,
    /// Notebook wave (mid-flash): count, arrival instant, runtime.
    pub notebooks: usize,
    pub notebook_at_s: u64,
    pub notebook_runtime_s: u64,
    pub placement: PlacementMode,
    pub loop_mode: LoopMode,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            seed: 20260807,
            horizon_s: 86_400, // one diurnal day
            sample_every_s: 3_600,
            base_rps: 500, // peak hour = 1.8M requests ≥ the 1M floor
            flash_at_s: 36_000,
            flash_len_s: 600,
            flash_rps: 2_400,
            slo_p99_us: 400_000,
            max_replicas: 12,
            static_mode: false,
            static_replicas: 12,
            notebooks: 4,
            notebook_at_s: 36_300,
            notebook_runtime_s: 7_200,
            placement: PlacementMode::Indexed,
            loop_mode: LoopMode::default(),
        }
    }
}

impl ServingConfig {
    /// Tier-1-friendly miniature (two simulated hours) for the parity
    /// and acceptance tests.
    pub fn small() -> Self {
        ServingConfig {
            horizon_s: 7_200,
            sample_every_s: 600,
            flash_at_s: 3_600,
            flash_len_s: 300,
            flash_rps: 600,
            // Two serving ticks after the flash-breach scale-up: the
            // fleet is still at the cohort ceiling on borrowed quota,
            // so the wave must reclaim.
            notebook_at_s: 3_610,
            ..Default::default()
        }
    }
}

#[derive(Debug)]
pub struct ServingResult {
    /// Time-series CSV: byte-identical across the 2×2 mode matrix.
    pub table: Table,
    /// The golden per-pod placement/phase CSV.
    pub placements: Table,
    pub arrived: u64,
    pub served: u64,
    pub queue_end: u64,
    pub slo_violations: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub slo_target_us: u64,
    /// GPU-replica occupancy, busy/allocated in ‰ — the metric the
    /// autoscaled run must strictly beat the static baseline on.
    pub occupancy_permille: u64,
    pub spawned: u64,
    pub retired: u64,
    pub live: u64,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub reclaim_evictions: u64,
    pub events_processed: u64,
    pub cycles: CycleCounts,
    /// `Cluster::check_accounting` at the horizon (None = clean).
    pub accounting_violation: Option<String>,
}

/// The replica shape every run uses: a 2g.10gb MIG slice of an A100
/// (2 compute units), so 12 replicas fit in 24 of the §2 rack's 35
/// A100 units.
fn replica_shape() -> Resources {
    Resources::notebook_gpu_slice(GpuModel::A100, SliceProfile::Mig2g10gb)
}

pub fn run_serving(cfg: &ServingConfig) -> ServingResult {
    // A local-quota scenario like the cohort phase: no federated sites
    // (slice pods are local-only anyway).
    let mut p = Platform::custom(
        scaled_farm(1),
        VirtualNodeController::new(),
        cfg.seed,
    );
    p.scheduler.mode = cfg.placement;
    p.periods.mode = cfg.loop_mode;

    // The cohort: notebooks own the larger share of the A100 slice
    // pool (16 units), serving owns 8 and may borrow the notebooks'
    // idle 16 — so a full 12-replica fleet (24 units) only exists on
    // borrowed quota, which is exactly what the reclaim wave takes
    // back.
    p.kueue.add_queue(
        ClusterQueue::with_nominal(
            "nb",
            QuotaVec::cpu(64_000).with_gpu_units(GpuModel::A100, 16),
        )
        .in_cohort("tenants"),
    );
    p.kueue.add_queue(
        ClusterQueue::with_nominal(
            "serving",
            QuotaVec::cpu(64_000).with_gpu_units(GpuModel::A100, 8),
        )
        .in_cohort("tenants")
        .borrowing(QuotaVec::cpu(64_000).with_gpu_units(GpuModel::A100, 16)),
    );

    let (min_replicas, max_replicas) = if cfg.static_mode {
        (cfg.static_replicas, cfg.static_replicas)
    } else {
        (1, cfg.max_replicas)
    };
    p.install_service(InferenceService {
        name: "flash-infer".into(),
        queue: "serving".into(),
        replica_shape: replica_shape(),
        batcher: BatcherPolicy {
            max_batch: 32,
            max_queue_delay_us: 20_000,
            batch_setup_us: 20_000,
            per_item_us: 2_500,
        },
        trace: TraceSpec {
            base_rps: cfg.base_rps,
            diurnal_pct: DIURNAL_DEFAULT,
            flash_at_s: cfg.flash_at_s,
            flash_len_s: cfg.flash_len_s,
            flash_rps: cfg.flash_rps,
        },
        slo: SloSpec { p99_target_us: cfg.slo_p99_us },
        min_replicas,
        max_replicas,
        scale_cooldown_s: 60,
        downscale_util_pct: 70,
    });

    let mut table = Table::new(&[
        "t_s",
        "replicas",
        "queue_len",
        "arrived_total",
        "served_total",
        "slo_violations",
        "borrowed_units",
        "running_pods",
    ]);
    let mut nb_submitted = false;
    let mut t = 0u64;
    while t < cfg.horizon_s {
        t += cfg.sample_every_s;
        // The notebook reclaim wave, on its exact grid instant.
        if !nb_submitted && cfg.notebooks > 0 && cfg.notebook_at_s <= t {
            p.run_until(cfg.notebook_at_s as f64);
            for _ in 0..cfg.notebooks {
                let pod = p.cluster.create_pod(
                    PodSpec::notebook(
                        "nb-user",
                        Resources::notebook_gpu_slice(
                            GpuModel::A100,
                            SliceProfile::Mig1g5gb,
                        ),
                    )
                    .with_runtime(cfg.notebook_runtime_s as f64),
                );
                p.kueue
                    .submit(pod, "nb", "nb-user", false, cfg.notebook_at_s as f64)
                    .expect("nb queue exists");
            }
            nb_submitted = true;
        }
        p.run_until(t as f64);
        let svc = p.serving.service("flash-infer").unwrap();
        let borrowed = p.kueue.queue("serving").unwrap().borrowed().gpu_units
            [GpuModel::A100.index()];
        table.push_row(&[
            t.to_string(),
            svc.replicas.len().to_string(),
            svc.queue_len.to_string(),
            svc.arrived_total.to_string(),
            svc.served_total.to_string(),
            svc.slo_violations.to_string(),
            borrowed.to_string(),
            p.cluster.running_pods().to_string(),
        ]);
    }

    let svc = p.serving.service("flash-infer").unwrap();
    let p50 = svc.latency_us.quantile(0.5);
    let p99 = svc.latency_us.quantile(0.99);
    ServingResult {
        arrived: svc.arrived_total,
        served: svc.served_total,
        queue_end: svc.queue_len,
        slo_violations: svc.slo_violations,
        p50_us: if p50.is_finite() { p50 as u64 } else { 0 },
        p99_us: if p99.is_finite() { p99 as u64 } else { u64::MAX },
        slo_target_us: cfg.slo_p99_us,
        occupancy_permille: if svc.alloc_us > 0 {
            svc.busy_us.saturating_mul(1000) / svc.alloc_us
        } else {
            0
        },
        spawned: svc.spawned,
        retired: svc.retired,
        live: svc.replicas.len() as u64,
        scale_ups: svc.scale_ups,
        scale_downs: svc.scale_downs,
        reclaim_evictions: p.kueue.n_reclaim_evictions,
        events_processed: p.events.processed(),
        cycles: p.cycles,
        accounting_violation: p.cluster.check_accounting().err(),
        placements: placements_table(&p),
        table,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn autoscaler_holds_slo_and_beats_static_occupancy() {
        let cfg = ServingConfig::small();
        let auto = run_serving(&cfg);
        assert!(auto.arrived > 500_000, "two simulated hours of traffic");
        assert_eq!(
            auto.arrived,
            auto.served + auto.queue_end,
            "requests conserved"
        );
        assert_eq!(auto.spawned - auto.retired, auto.live);
        assert!(
            auto.p99_us <= auto.slo_target_us,
            "p99 {}µs blew the {}µs SLO ({} violations of {})",
            auto.p99_us,
            auto.slo_target_us,
            auto.slo_violations,
            auto.served
        );
        assert!(auto.scale_ups >= 2, "bootstrap + flash breach");
        assert!(auto.scale_downs >= 1, "post-flash shrink");
        assert!(
            auto.reclaim_evictions >= 1,
            "the mid-flash notebook wave reclaims borrowed quota"
        );
        assert_eq!(auto.accounting_violation, None);

        let mut static_cfg = cfg;
        static_cfg.static_mode = true;
        let fixed = run_serving(&static_cfg);
        assert!(fixed.p99_us <= fixed.slo_target_us, "overprovisioned");
        assert_eq!(fixed.scale_downs, 0, "static fleet never shrinks");
        assert!(
            auto.occupancy_permille > fixed.occupancy_permille,
            "autoscaled occupancy {}‰ must beat static {}‰",
            auto.occupancy_permille,
            fixed.occupancy_permille
        );
    }

    #[test]
    fn serving_modes_agree_pairwise() {
        let mut cfg = ServingConfig::small();
        let mut runs = Vec::new();
        for placement in [PlacementMode::Indexed, PlacementMode::LinearScan] {
            for loop_mode in [LoopMode::Polling, LoopMode::Reactive] {
                cfg.placement = placement;
                cfg.loop_mode = loop_mode;
                let r = run_serving(&cfg);
                runs.push((
                    format!("{placement:?}/{loop_mode:?}"),
                    r.placements.to_csv(),
                    r.table.to_csv(),
                ));
            }
        }
        for pair in runs.windows(2) {
            assert_eq!(
                pair[0].1, pair[1].1,
                "placements diverged: {} vs {}",
                pair[0].0, pair[1].0
            );
            assert_eq!(
                pair[0].2, pair[1].2,
                "time-series diverged: {} vs {}",
                pair[0].0, pair[1].0
            );
        }
    }

    #[test]
    fn serving_same_seed_same_bytes() {
        let cfg = ServingConfig::small();
        let a = run_serving(&cfg);
        let b = run_serving(&cfg);
        assert_eq!(a.table.to_csv(), b.table.to_csv());
        assert_eq!(a.placements.to_csv(), b.placements.to_csv());
        assert_eq!(a.events_processed, b.events_processed);
    }
}
