//! The platform coordinator: composes every subsystem into the running
//! AI_INFN platform and drives scenarios on the discrete-event engine.
//!
//! This is the Layer-3 "leader": the event loop owns the cluster state,
//! routes hub spawns (with the §4 Kueue contention path), runs Kueue
//! admission cycles, reconciles the virtual-node controller against the
//! site plugins, scrapes monitoring, and updates accounting — the same
//! loop the real platform distributes across controllers.
//!
//! ## Edge-triggered with level-triggered fallback
//!
//! The loop runs in one of two [`LoopMode`]s:
//!
//! * [`LoopMode::Polling`] — the seed's loop, kept as the oracle: every
//!   controller cycle re-arms itself at a fixed period and runs whether
//!   or not there is work.
//! * [`LoopMode::Reactive`] — demand-driven: subsystems raise *dirty*
//!   edges on every mutating path (Kueue: pending-set/quota delta,
//!   including the quota tree's borrow/reclaim cascade — a reclaim
//!   eviction inside an admission cycle requeues the borrower, frees
//!   capacity and respawns its pod, so both the Kueue and cluster
//!   edges fire and the next admission cycle arms itself on the grid;
//!   cluster: capacity release; vnode controller: remote-state change,
//!   with [`crate::offload::VirtualNodeController::next_transition_after`]
//!   predicting site-internal transitions; hub: session lifecycle;
//!   scheduler: uncordon), and the coordinator arms the matching cycle
//!   as a *keyed one-shot timer* — duplicate signals coalesce into the
//!   already-pending wakeup. A low-frequency level-triggered sweep
//!   ([`Periods::sweep`]) re-runs every demand cycle regardless, as the
//!   safety net real controllers keep (the resync period).
//!
//! ## Shard-hinted wakeups (PR-9)
//!
//! Cluster capacity edges carry the owning shard
//! ([`Cluster::take_dirty_shards`]), and the reactive loop arms them
//! as *per-shard* one-shot timers (`KEY_SHARD_ADMISSION_BASE + s`)
//! rather than one global admission wakeup. The invariant is
//! edge/level split: **edges prune, levels sweep.** A shard-hinted
//! edge wakes the admission cycle on the usual grid instant but the
//! cycle's searches stay scoped to the edged shards (see
//! [`crate::kueue::Kueue::shard_scoped`] — exact pruning, so
//! decisions are unchanged); the periodic sweep, a level signal with
//! no edge attribution, re-opens and visits every shard. Polling mode
//! arms no shard timers and scopes nothing — it remains the
//! level-triggered oracle the golden tests diff against. Whichever
//! admission-class timer pops first at an instant runs one cycle on
//! behalf of all of them and cancels the rest, so cycle and event
//! counts match the un-sharded reactive loop exactly.
//!
//! ## Why decisions are byte-identical across modes
//!
//! Reactive wakeups are quantized onto the polling grid: a dirty edge
//! at time `d` arms its cycle at the smallest multiple of the cycle's
//! period that the polling loop would have used to observe it. A
//! polling cycle the reactive loop *skips* is therefore always one
//! whose subsystem raised no edge since the cycle's previous run — and
//! every such cycle is a no-op by construction (an admission pass over
//! an unchanged pending set/cluster admits nothing and mutates nothing;
//! a reconcile tick with no site transition, launch or retry mutates
//! nothing under the sites' fixed pass cadence; a cull pass before any
//! session's idle deadline culls nothing). Same-instant interleaving is
//! pinned by event *classes* (see [`crate::sim`]): at a shared grid
//! instant, cycles pop in descending-period order (cull → accounting →
//! scrape → reconcile → admission) before any payload event, in both
//! modes, regardless of when a wakeup was armed. The serving cycle
//! (class 45, between reconcile and admission) is the one
//! level-triggered controller besides observability: request traces
//! are perpetual demand, so while services are installed it re-arms
//! every [`Periods::serving`] in both modes — which is exactly what
//! makes its scale decisions and replica submissions byte-identical
//! across the mode matrix. The equality holds on
//! the polling grid — periods whose multiples are exact in f64 (the
//! defaults, and any integer-second periods).
//!
//! Verified end-to-end by the golden cross-mode placement/phase CSVs in
//! `experiments::fed_stress` / `experiments::fig2`.

use crate::chaos::{FaultKind, FaultPlan};
use crate::cluster::{
    ai_infn_farm, Cluster, Node, PodId, PodPhase, ScheduleError, Scheduler,
    ScoringPolicy, ShardSet,
};
use crate::hub::{Hub, HubError, SessionId};
use crate::iam::Iam;
use crate::kueue::{Kueue, WorkloadId, WorkloadState};
use crate::monitoring::{scrape_all, Accounting, Tsdb};
use crate::offload::{plugins, VirtualNodeController};
use crate::sim::{EventQueue, Time, TimerKey, Trace, CLASS_NORMAL};
use crate::storage::ephemeral::EphemeralManager;
use crate::storage::nfs::NfsServer;
use crate::util::bytes::GIB;
use crate::util::rng::Rng;
use crate::vkd::Vkd;
use crate::workload::fl::{FlAction, FlSpec, FlState};
use crate::workload::serving::{InferenceService, ScaleAction, ServingState};

/// Platform event loop payloads.
#[derive(Debug)]
pub enum Event {
    /// Kueue admission pass.
    AdmissionCycle,
    /// Virtual-kubelet reconcile (site ticks + status sync).
    Reconcile,
    /// Prometheus scrape.
    Scrape,
    /// Accounting aggregation.
    AccountingUpdate,
    /// A locally-running batch pod finishes.
    LocalJobDone(PodId),
    /// A notebook session ends (user closes / culler).
    SessionEnds(SessionId),
    /// Idle-culler pass.
    CullPass,
    /// Inference-serving tick: advance traces/batchers, evaluate the
    /// autoscalers, submit/retire replica pods. Armed only while
    /// services are installed (see [`Platform::install_service`]).
    ServingCycle,
    /// Fault-injection tick: apply every [`FaultPlan`] event due at
    /// this instant and drive the recovery path (cordon/drain, Kueue
    /// fault requeue, node reboot). Armed as a keyed timer at the
    /// plan's next fault instant in BOTH loop modes (see
    /// [`Platform::install_chaos`]) — chaos cycles fire only when
    /// faults are due, at identical instants across the mode matrix.
    ChaosCycle,
    /// Federated-learning tick: advance the round state machine one
    /// phase-step (Select → Distribute → Update → Sum → Commit) and
    /// execute its pod/session actions. Level-triggered in BOTH loop
    /// modes while rounds remain (see [`Platform::install_fl`]) — a
    /// round in flight is perpetual demand, exactly like a serving
    /// trace — so every phase transition lands on identical instants
    /// across the mode matrix.
    FlCycle,
}

// Same-instant ordering classes, descending period: at a shared grid
// instant the polling loop's steady state pops the longest-period cycle
// first (it was armed earliest, so it carries the oldest seq). Classes
// make that order explicit and arming-time-independent, which is what
// lets a demand-armed cycle interleave exactly like a periodic one.
const CLASS_CULL: u8 = 10;
const CLASS_ACCOUNTING: u8 = 20;
// Chaos pops *before* the mutating cycles at a shared instant: a fault
// lands, then the same instant's admission/reconcile observe the
// post-fault state — in both modes, since fault instants are
// grid-aligned by the backoff-on-grid contract (`crate::chaos`).
const CLASS_CHAOS: u8 = 25;
const CLASS_SCRAPE: u8 = 30;
const CLASS_RECONCILE: u8 = 40;
// FL pops before serving and admission at a shared instant: a round's
// trainer/aggregator submissions are admitted by the same instant's
// admission cycle in both loop modes, and FL's quota churn is visible
// to the serving tick that shares the instant.
const CLASS_FL: u8 = 44;
// Serving pops *before* admission at a shared instant so the pods a
// serving tick submits are admitted by the same instant's admission
// cycle in both loop modes.
const CLASS_SERVING: u8 = 45;
const CLASS_ADMISSION: u8 = 50;

// Keyed-timer identities for the demand-driven cycles.
const KEY_ADMISSION: TimerKey = 1;
const KEY_RECONCILE: TimerKey = 2;
const KEY_CULL: TimerKey = 3;
const KEY_SERVING: TimerKey = 4;
const KEY_CHAOS: TimerKey = 5;
const KEY_FL: TimerKey = 6;
// Per-shard admission wakeups (PR-9): shard `s`'s one-shot timer is
// key `BASE + s`. All land on the admission grid with the admission
// class, so whichever pops first at an instant runs ONE cycle on
// behalf of every armed shard and cancels the rest — a capacity edge
// in one zone wakes the loop without costing extra cycles, and the
// cycle's zone scoping (`Kueue::shard_scoped`) keeps the *search*
// from touching un-edged zones. Keys 7..15 stay reserved for future
// singleton cycles.
const KEY_SHARD_ADMISSION_BASE: TimerKey = 16;

impl Event {
    fn class(&self) -> u8 {
        match self {
            Event::CullPass => CLASS_CULL,
            Event::AccountingUpdate => CLASS_ACCOUNTING,
            Event::ChaosCycle => CLASS_CHAOS,
            Event::Scrape => CLASS_SCRAPE,
            Event::Reconcile => CLASS_RECONCILE,
            Event::FlCycle => CLASS_FL,
            Event::ServingCycle => CLASS_SERVING,
            Event::AdmissionCycle => CLASS_ADMISSION,
            Event::LocalJobDone(_) | Event::SessionEnds(_) => CLASS_NORMAL,
        }
    }
}

/// How the coordinator schedules its controller cycles.
///
/// The library default is [`LoopMode::Reactive`] (flipped in PR 4,
/// after the edge-triggered loop soaked under the PR-3 cross-mode
/// goldens): every scenario that does not opt out runs demand-driven.
/// [`LoopMode::Polling`] is kept as the equivalence oracle — the
/// golden tests pin both modes explicitly and the BENCH trajectory
/// labels each entry's mode, so the flip changes no recorded
/// comparison.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LoopMode {
    /// Fixed-period cycles (the seed's loop; the equivalence oracle).
    Polling,
    /// Demand-driven cycles armed by subsystem dirty edges, quantized
    /// onto the polling grid, plus the [`Periods::sweep`] safety net.
    #[default]
    Reactive,
}

/// The loop's wakeup policy: cycle periods (the grid), the loop mode,
/// and the reactive sweep interval. Set `mode` before the first
/// `run_until`; switching mid-run is safe (each cycle re-arms under the
/// current mode when it fires) but the cross-mode byte-equality
/// guarantee only covers whole runs.
#[derive(Clone, Debug)]
pub struct Periods {
    pub admission: f64,
    pub reconcile: f64,
    pub scrape: f64,
    pub accounting: f64,
    pub cull: f64,
    /// Serving-tick grid. Trace arrivals are a perpetual demand signal,
    /// so this cycle is level-triggered in *both* modes while services
    /// are installed — keep it a divisor-aligned multiple of
    /// `admission` so a tick's replica submissions are admitted at the
    /// same instant in both modes.
    pub serving: f64,
    /// Fault-injection grid: every [`FaultPlan`] instant must be a
    /// multiple of this, and this must itself be a multiple of
    /// `admission`, so a fault instant is always an admission instant
    /// too (the chaos module's backoff-on-grid contract). The chaos
    /// cycle is keyed-armed at the plan's next fault in both modes —
    /// never polled.
    pub chaos: f64,
    /// Federated-learning tick grid. A round in flight is perpetual
    /// demand (arrival curves advance every second), so the FL cycle is
    /// level-triggered in both modes while rounds remain — like
    /// `serving`, keep it a divisor-aligned multiple of `admission` so
    /// a tick's pod submissions are admitted at the same instant in
    /// both modes.
    pub fl: f64,
    pub mode: LoopMode,
    /// Reactive level-triggered sweep: every demand cycle also re-runs
    /// at most this many seconds after its previous run (grid-aligned),
    /// signals or not.
    pub sweep: f64,
}

impl Default for Periods {
    fn default() -> Self {
        Periods {
            admission: 5.0,
            reconcile: 10.0,
            scrape: 60.0,
            accounting: 300.0,
            cull: 600.0,
            serving: 5.0,
            chaos: 5.0,
            fl: 5.0,
            mode: LoopMode::default(),
            sweep: 600.0,
        }
    }
}

/// How many times each controller cycle actually ran — the reactive
/// loop's headline observable (fed_stress records these next to
/// events/sec in `BENCH_sched_index.json`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CycleCounts {
    pub admission: u64,
    pub reconcile: u64,
    pub scrape: u64,
    pub accounting: u64,
    pub cull: u64,
    pub serving: u64,
    pub chaos: u64,
    pub fl: u64,
}

impl CycleCounts {
    /// Total controller cycles (the "coordinator events" of the
    /// reactive-loop acceptance criterion).
    pub fn total(&self) -> u64 {
        self.admission
            + self.reconcile
            + self.scrape
            + self.accounting
            + self.cull
            + self.serving
            + self.chaos
            + self.fl
    }
}

/// How the platform answers a fault: how hard an evicted workload backs
/// off before its next admission attempt, and how many fault-requeues
/// it is granted before going terminal-Failed. Lives coordinator-side
/// (passed into [`crate::kueue::Kueue::requeue_faulted`] per call) so
/// `Kueue::default()` stays an all-zeros derive.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RecoveryPolicy {
    /// Backoff base: after its k-th fault eviction a workload is held
    /// out of admission until `now + base · 2^(k-1)` — *effective* at
    /// the first admission-grid instant at or past that deadline.
    pub backoff_base_s: f64,
    /// Fault evictions beyond this count turn the workload
    /// terminal-Failed with the reason stamped on its pod.
    pub retry_budget: u32,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy { backoff_base_s: 10.0, retry_budget: 5 }
    }
}

/// Live fault-injection state: the plan cursor, crashed nodes held for
/// reboot, the recovery policy, and the chaos counters monitoring
/// exports (`export_chaos`).
#[derive(Clone, Debug, Default)]
pub struct ChaosRuntime {
    pub plan: FaultPlan,
    /// Crashed nodes, keyed by name, held (fully drained and free)
    /// until their `NodeReboot` event re-adds them under the same
    /// interned id.
    pub down: std::collections::BTreeMap<String, Node>,
    pub policy: RecoveryPolicy,
    pub n_node_failures: u64,
    pub n_node_reboots: u64,
    pub n_gpu_failures: u64,
    pub n_site_outages: u64,
    /// Pods evicted by faults (drain + device retirement victims).
    pub n_pods_evicted: u64,
}

/// The composed platform.
pub struct Platform {
    pub cluster: Cluster,
    pub scheduler: Scheduler,
    pub iam: Iam,
    pub hub: Hub,
    pub kueue: Kueue,
    pub vkd: Vkd,
    pub vk: VirtualNodeController,
    pub nfs: NfsServer,
    pub ephemeral: EphemeralManager,
    pub tsdb: Tsdb,
    pub accounting: Accounting,
    pub events: EventQueue<Event>,
    pub trace: Trace,
    pub rng: Rng,
    pub periods: Periods,
    pub cycles: CycleCounts,
    pub serving: ServingState,
    /// Federated-learning rounds, when installed
    /// ([`Platform::install_fl`]).
    pub fl: FlState,
    /// Fault injection, when installed ([`Platform::install_chaos`]).
    pub chaos: Option<ChaosRuntime>,
    /// Workloads whose local pods have a scheduled completion event.
    local_running: std::collections::BTreeMap<PodId, WorkloadId>,
    /// Shards with a pending per-shard admission wakeup (reactive
    /// mode): armed by capacity edges in [`Platform::react`], drained
    /// by the next admission cycle.
    armed_shards: ShardSet,
    /// Whether the pending `KEY_ADMISSION` wakeup was armed by a
    /// demand edge (Kueue/scheduler dirt or a fault-backoff deadline)
    /// rather than the level-triggered sweep. A cycle attributable to
    /// neither a demand edge nor an armed shard is a sweep and
    /// re-opens every shard for the zone-scoped search.
    admission_demand: bool,
    /// Per-shard count of admission cycles run on behalf of that
    /// shard's wakeup timer (the `export_loop_shards` gauges).
    pub shard_wakeups: Vec<u64>,
}

impl std::fmt::Debug for Platform {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Platform")
            .field("now", &self.events.now())
            .field("nodes", &self.cluster.nodes().count())
            .field("pods_running", &self.cluster.running_pods())
            .finish()
    }
}

/// Smallest multiple of `period` that is ≥ `target` and, when `strict`,
/// also > `now` — the polling-grid instant a reactive wakeup lands on.
///
/// Public for the grid-exactness property tests
/// (`rust/tests/loop_grid.rs`): the cross-mode byte-equality contract
/// holds exactly when the polling loop's repeated-addition re-arm
/// trajectory (`t += period`) coincides with these quantized
/// multiples — true for every grid-exact period (integer seconds, and
/// any dyadic fraction), pinned false for non-representable periods
/// like 0.1 s.
pub fn grid_at(period: f64, target: Time, now: Time, strict: bool) -> Time {
    debug_assert!(period > 0.0 && period.is_finite());
    let mut g = (target / period).ceil() * period;
    while g < target {
        g += period; // f64 ceil guard
    }
    while g < now || (strict && g == now) {
        g += period;
    }
    g
}

impl Platform {
    /// The paper's platform: §2 farm + §4 federated sites.
    pub fn ai_infn(seed: u64) -> Self {
        let mut cluster = ai_infn_farm();
        let mut vk = VirtualNodeController::new();
        for site in plugins::fig2_testbed(seed) {
            vk.register_site(&mut cluster, site);
        }
        Self::with_parts(cluster, vk, seed)
    }

    /// Local-only platform (no federation) — the MOT1 baseline.
    pub fn local_only(seed: u64) -> Self {
        Self::with_parts(ai_infn_farm(), VirtualNodeController::new(), seed)
    }

    /// A platform over an arbitrary cluster + federation — the
    /// federation stress scenario builds its scaled farm through this.
    pub fn custom(
        cluster: Cluster,
        vk: VirtualNodeController,
        seed: u64,
    ) -> Self {
        Self::with_parts(cluster, vk, seed)
    }

    fn with_parts(
        cluster: Cluster,
        vk: VirtualNodeController,
        seed: u64,
    ) -> Self {
        let mut ephemeral = EphemeralManager::new();
        for node in cluster.nodes().filter(|n| n.capacity.nvme > 0) {
            ephemeral.register_node(&node.name, node.capacity.nvme);
        }
        let mut p = Platform {
            cluster,
            scheduler: Scheduler::new(),
            iam: Iam::new(seed),
            hub: Hub::new(),
            kueue: Kueue::new(),
            vkd: Vkd::new(),
            vk,
            nfs: NfsServer::new(100 * GIB),
            ephemeral,
            tsdb: Tsdb::new(),
            accounting: Accounting::new(3600.0),
            events: EventQueue::new(),
            trace: Trace::new(10_000, false),
            rng: Rng::new(seed),
            periods: Periods::default(),
            cycles: CycleCounts::default(),
            serving: ServingState::default(),
            fl: FlState::default(),
            chaos: None,
            local_running: Default::default(),
            armed_shards: ShardSet::new(),
            admission_demand: false,
            shard_wakeups: Vec::new(),
        };
        // Prime every cycle at t=0. The demand cycles are primed as
        // keyed timers so a reactive `react()` before the first event
        // coalesces into them instead of double-scheduling; in polling
        // mode the keys simply free at the first fire.
        p.events.schedule_keyed(
            KEY_ADMISSION,
            0.0,
            CLASS_ADMISSION,
            Event::AdmissionCycle,
        );
        p.events
            .schedule_keyed(KEY_RECONCILE, 0.0, CLASS_RECONCILE, Event::Reconcile);
        p.events.at_class(0.0, CLASS_SCRAPE, Event::Scrape);
        p.events
            .at_class(0.0, CLASS_ACCOUNTING, Event::AccountingUpdate);
        p.events.schedule_keyed(KEY_CULL, 0.0, CLASS_CULL, Event::CullPass);
        p
    }

    pub fn now(&self) -> Time {
        self.events.now()
    }

    /// Install an inference service and arm its first serving tick on
    /// the grid. The cycle is deliberately NOT primed in `with_parts`:
    /// a platform with no services must run zero serving cycles (the
    /// idle-reactive cycle-count invariants depend on it).
    pub fn install_service(&mut self, spec: InferenceService) {
        self.serving.install(spec);
        let now = self.events.now();
        let at = grid_at(self.periods.serving, now, now, false);
        self.arm_at(KEY_SERVING, at);
    }

    /// Install a federated-learning job and arm its first FL tick on
    /// the grid. Like `install_service`, the cycle is deliberately NOT
    /// primed in `with_parts` — a platform with no FL job must run zero
    /// FL cycles (the idle-reactive cycle-count invariants depend on
    /// it) — and it stops re-arming once every round has committed, so
    /// a finished job costs zero further events. The coordinator's
    /// dev-loop identity is registered here so each round's hub
    /// session spawn can authenticate.
    pub fn install_fl(&mut self, spec: FlSpec) {
        self.iam.register("fl-coordinator", "FL Coordinator", &[]);
        self.fl.install(spec);
        let now = self.events.now();
        let at = grid_at(self.periods.fl, now, now, false);
        self.arm_at(KEY_FL, at);
    }

    /// Install a fault plan and arm the chaos cycle at its first fault
    /// instant — as a keyed timer in BOTH loop modes, so fault
    /// application instants (and counts) are identical across the mode
    /// matrix and an idle plan costs zero cycles. Site outage windows
    /// are registered on their [`crate::offload::SiteModel`]s here, up
    /// front (the windows are data, not events); the plan's
    /// `SiteOutage` events then only count. Like `install_service`,
    /// this is deliberately not primed in `with_parts`: a platform
    /// without chaos runs zero chaos cycles.
    ///
    /// The plan must satisfy [`FaultPlan::on_grid`] for
    /// [`Periods::chaos`] — asserted here, since off-grid fault
    /// instants silently void the cross-mode byte-equality contract.
    pub fn install_chaos(&mut self, plan: FaultPlan, policy: RecoveryPolicy) {
        assert!(
            plan.on_grid(self.periods.chaos),
            "fault plan instants must be multiples of periods.chaos"
        );
        for ev in plan.events() {
            if let FaultKind::SiteOutage { site, until } = &ev.kind {
                if let Some(s) = self.vk.site_mut(site) {
                    s.add_outage(ev.at, *until);
                }
            }
        }
        let now = self.events.now();
        if let Some(at) = plan.next_at() {
            let g = grid_at(self.periods.chaos, at.max(now), now, false);
            self.arm_at(KEY_CHAOS, g);
        }
        self.chaos = Some(ChaosRuntime {
            plan,
            policy,
            ..ChaosRuntime::default()
        });
    }

    /// Spawn a notebook with the §4 contention path: if the pod cannot
    /// be placed, Kueue evicts opportunistic batch to make room.
    pub fn spawn_notebook(
        &mut self,
        subject: &str,
        profile: &str,
        now: Time,
    ) -> Result<SessionId, HubError> {
        let token = self
            .iam
            .issue_token(subject, now)
            .map_err(|e| HubError::Auth(format!("{e:?}")))?;
        let cluster = &mut self.cluster;
        let sid = self.hub.begin_spawn(
            &self.iam,
            &token,
            profile,
            &mut self.nfs,
            now,
            |spec| cluster.create_pod(spec),
        )?;
        let pod = self.hub.session(sid).unwrap().pod;
        match self.scheduler.schedule(&mut self.cluster, pod, ScoringPolicy::BinPack)
        {
            Ok(node) => {
                let msg = format!(
                    "spawn {} on {}",
                    self.hub.session(sid).unwrap().name,
                    self.cluster.name_of(node)
                );
                self.trace.log(now, msg);
            }
            Err(ScheduleError::NoCapacity) => {
                // §4: batch is "immediately evicted in case new notebook
                // instances are spawned".
                match self.kueue.make_room_for_notebook(
                    &mut self.cluster,
                    &self.scheduler,
                    pod,
                ) {
                    Ok((node, evicted)) => {
                        let msg = format!(
                            "spawn {} on {} after evicting {} batch pods",
                            self.hub.session(sid).unwrap().name,
                            self.cluster.name_of(node),
                            evicted.len()
                        );
                        self.trace.log(now, msg);
                        self.kueue.respawn_evicted_pods(&mut self.cluster);
                    }
                    Err(e) => {
                        // Roll the session back.
                        let _ = self.hub.stop(sid, &mut self.nfs);
                        let _ = self.cluster.delete_pod(pod);
                        return Err(HubError::Auth(format!(
                            "no capacity and no preemption plan: {e}"
                        )));
                    }
                }
            }
            Err(ScheduleError::Unschedulable(e)) => {
                let _ = self.hub.stop(sid, &mut self.nfs);
                let _ = self.cluster.delete_pod(pod);
                return Err(HubError::Auth(format!("unschedulable: {e}")));
            }
        }
        self.hub.activate(sid, now).unwrap();
        self.accounting.record_session(subject, now);
        // Ephemeral scratch volume on the session's node (the pool map
        // is name-keyed — a boundary structure, so resolve the handle
        // and the session's display name).
        let node = self.cluster.pod(pod).unwrap().node.unwrap();
        let node_name = self.cluster.name_of(node);
        if self.ephemeral.pool_free(node_name).unwrap_or(0) > 100 * GIB {
            let session_name = self.hub.session(sid).unwrap().name.clone();
            let _ = self
                .ephemeral
                .create_volume(&session_name, node_name, 100 * GIB);
        }
        Ok(sid)
    }

    /// End a session: stop in hub, free pod, destroy scratch.
    pub fn end_session(&mut self, sid: SessionId) -> Result<(), String> {
        let pod = self
            .hub
            .stop(sid, &mut self.nfs)
            .map_err(|e| format!("{e:?}"))?;
        if self.cluster.pod(pod).map(|p| p.phase) == Some(PodPhase::Running) {
            self.cluster.complete(pod)?;
        } else {
            let _ = self.cluster.delete_pod(pod);
        }
        // The ephemeral pool is keyed by the session's display name.
        if let Some(name) = self.hub.session(sid).map(|s| s.name.clone()) {
            let _ = self.ephemeral.destroy_volume(&name);
        }
        Ok(())
    }

    /// Handle one event. Cycles re-arm themselves according to the
    /// loop mode: periodically under [`LoopMode::Polling`], by
    /// demand/sweep under [`LoopMode::Reactive`] (followed by a
    /// [`Platform::react`] pass that converts any dirty edges this
    /// event raised into wakeups).
    pub fn handle(&mut self, t: Time, ev: Event) {
        let class = ev.class();
        match ev {
            Event::AdmissionCycle => {
                self.cycles.admission += 1;
                // Zone scoping follows the loop mode (robust to a
                // mid-run flip): reactive prunes, polling stays the
                // level-triggered oracle over every shard.
                self.kueue.shard_scoped =
                    self.periods.mode == LoopMode::Reactive;
                if self.periods.mode == LoopMode::Reactive {
                    // Absorb every same-purpose wakeup: whichever
                    // timer popped (KEY_ADMISSION or a per-shard key)
                    // runs ONE cycle on behalf of all of them, and
                    // the rest are cancelled — so the cycle count
                    // matches the un-sharded reactive loop exactly.
                    let demand = self.admission_demand
                        || !self.armed_shards.is_empty();
                    if !demand {
                        // Not attributable to any recorded edge: the
                        // level-triggered sweep (or a backoff-deadline
                        // wakeup). Re-open every shard so the safety
                        // net really visits them all.
                        self.kueue.note_capacity_edge_all();
                    }
                    self.admission_demand = false;
                    self.events.cancel_keyed(KEY_ADMISSION);
                    let armed = self.armed_shards.take();
                    for s in armed.iter() {
                        if s >= self.shard_wakeups.len() {
                            self.shard_wakeups.resize(s + 1, 0);
                        }
                        self.shard_wakeups[s] += 1;
                        self.events.cancel_keyed(
                            KEY_SHARD_ADMISSION_BASE + s as TimerKey,
                        );
                    }
                }
                let admitted = self.kueue.admission_cycle(
                    &mut self.cluster,
                    &self.scheduler,
                    t,
                );
                for wl in admitted {
                    self.on_admitted(wl, t);
                }
                match self.periods.mode {
                    LoopMode::Polling => self.events.after_class(
                        self.periods.admission,
                        CLASS_ADMISSION,
                        Event::AdmissionCycle,
                    ),
                    LoopMode::Reactive => {
                        // A workload backing off after a fault eviction
                        // raises no dirty edge when its deadline
                        // passes — time is not an edge. Arm the next
                        // cycle at the earliest backoff deadline (grid-
                        // quantized by arm_demand), else at the sweep.
                        let mut target =
                            t + self.periods.sweep.max(self.periods.admission);
                        if let Some(nb) = self.kueue.next_not_before(t) {
                            target = target.min(nb);
                        }
                        self.arm_demand(KEY_ADMISSION, target, Some(class));
                    }
                }
            }
            Event::Reconcile => {
                self.cycles.reconcile += 1;
                let finished = self.vk.reconcile(&mut self.cluster, t);
                for (pod, state) in finished {
                    // O(log n) pod→workload lookup instead of scanning
                    // every workload per finished remote job.
                    let wl = self.kueue.workload_of_pod(pod).filter(|wid| {
                        self.kueue
                            .workload(*wid)
                            .map(|w| w.state == WorkloadState::Admitted)
                            .unwrap_or(false)
                    });
                    if let Some(wl) = wl {
                        let ok = state == crate::offload::RemoteState::Succeeded;
                        let _ = self.kueue.finish(&self.cluster, wl, ok, t);
                    }
                }
                match self.periods.mode {
                    LoopMode::Polling => self.events.after_class(
                        self.periods.reconcile,
                        CLASS_RECONCILE,
                        Event::Reconcile,
                    ),
                    LoopMode::Reactive => {
                        let mut target =
                            t + self.periods.sweep.max(self.periods.reconcile);
                        if let Some(d) = self.vk.next_transition_after(t) {
                            target = target.min(d);
                        }
                        self.arm_demand(KEY_RECONCILE, target, Some(class));
                    }
                }
            }
            Event::Scrape => {
                self.cycles.scrape += 1;
                scrape_all(
                    &mut self.tsdb,
                    &self.cluster,
                    &self.nfs,
                    &self.kueue,
                    &self.vk,
                    &self.shard_wakeups,
                    t,
                );
                if self.serving.installed() {
                    crate::monitoring::export_serving(
                        &mut self.tsdb,
                        &self.serving,
                        t,
                    );
                }
                if let Some(chaos) = &self.chaos {
                    crate::monitoring::export_chaos(
                        &mut self.tsdb,
                        &self.kueue,
                        &self.vk,
                        chaos,
                        t,
                    );
                }
                if self.fl.installed() {
                    crate::monitoring::export_fl(&mut self.tsdb, &self.fl, t);
                }
                // Observability stays level-triggered in both modes: a
                // periodic scrape is the Prometheus contract, and at a
                // shared instant its class (30) orders it before the
                // mutating cycles, so both modes scrape identical state.
                self.events
                    .after_class(self.periods.scrape, CLASS_SCRAPE, Event::Scrape);
            }
            Event::AccountingUpdate => {
                self.cycles.accounting += 1;
                self.accounting.update(&self.cluster, t);
                self.events.after_class(
                    self.periods.accounting,
                    CLASS_ACCOUNTING,
                    Event::AccountingUpdate,
                );
            }
            Event::LocalJobDone(pod) => {
                if self.cluster.pod(pod).map(|p| p.phase)
                    == Some(PodPhase::Running)
                {
                    let _ = self.cluster.complete(pod);
                    if let Some(wl) = self.local_running.remove(&pod) {
                        let _ = self.kueue.finish(&self.cluster, wl, true, t);
                    }
                }
            }
            Event::SessionEnds(sid) => {
                let _ = self.end_session(sid);
            }
            Event::ServingCycle => {
                self.cycles.serving += 1;
                self.serving_cycle(t);
                // Trace arrivals are perpetual demand: while services
                // are installed the tick re-arms every period in BOTH
                // modes, so tick instants — and therefore every scale
                // decision and replica submission — are identical
                // across modes by construction.
                if self.serving.installed() {
                    match self.periods.mode {
                        LoopMode::Polling => self.events.after_class(
                            self.periods.serving,
                            CLASS_SERVING,
                            Event::ServingCycle,
                        ),
                        LoopMode::Reactive => self.arm_demand(
                            KEY_SERVING,
                            t + self.periods.serving,
                            Some(class),
                        ),
                    }
                }
            }
            Event::FlCycle => {
                self.cycles.fl += 1;
                self.fl_cycle(t);
                // A round in flight is perpetual demand: while rounds
                // remain the tick re-arms every period in BOTH modes,
                // so phase transitions — and therefore every cohort
                // decision and pod submission — land on identical
                // instants across modes by construction. Once the last
                // round commits (`active()` false) it stops for good.
                if self.fl.active() {
                    match self.periods.mode {
                        LoopMode::Polling => self.events.after_class(
                            self.periods.fl,
                            CLASS_FL,
                            Event::FlCycle,
                        ),
                        LoopMode::Reactive => self.arm_demand(
                            KEY_FL,
                            t + self.periods.fl,
                            Some(class),
                        ),
                    }
                }
            }
            Event::ChaosCycle => {
                self.cycles.chaos += 1;
                self.chaos_cycle(t);
                // Re-arm at the next fault instant — keyed, both
                // modes; a finished plan arms nothing.
                if let Some(at) =
                    self.chaos.as_ref().and_then(|c| c.plan.next_at())
                {
                    let g = grid_at(self.periods.chaos, at, t, false);
                    self.arm_at(KEY_CHAOS, g);
                }
            }
            Event::CullPass => {
                self.cycles.cull += 1;
                for sid in self.hub.cull_candidates(t) {
                    self.trace.log(t, format!("culling idle session {sid}"));
                    let _ = self.end_session(sid);
                }
                match self.periods.mode {
                    LoopMode::Polling => self.events.after_class(
                        self.periods.cull,
                        CLASS_CULL,
                        Event::CullPass,
                    ),
                    LoopMode::Reactive => {
                        let mut target =
                            t + self.periods.sweep.max(self.periods.cull);
                        if let Some(d) = self.hub.next_cull_time() {
                            target = target.min(d.max(t));
                        }
                        self.arm_demand(KEY_CULL, target, Some(class));
                    }
                }
            }
        }
        if self.periods.mode == LoopMode::Reactive {
            self.react(Some(class));
        }
    }

    /// Reactive core: convert the subsystems' dirty edges into keyed,
    /// grid-aligned wakeups. `during` is the class of the event being
    /// handled (None when called outside event handling, e.g. at
    /// `run_until` entry after external mutations): a cycle may reuse
    /// the *current* instant's grid slot only if its class pops after
    /// the current event — exactly when the polling loop's cycle at
    /// this instant would still be ahead in the queue.
    fn react(&mut self, during: Option<u8>) {
        // Only the reactive call sites reach here; in polling mode the
        // dirty flags are simply never consumed (signals accumulate,
        // unread — harmless, and a mid-run switch to Reactive drains
        // them at its first react).
        debug_assert_eq!(self.periods.mode, LoopMode::Reactive);
        let kueue_dirty = self.kueue.take_dirty();
        let shard_edges = self.cluster.take_dirty_shards();
        let cluster_dirty = !shard_edges.is_empty();
        let sched_dirty = self.scheduler.take_dirty();
        let vk_dirty = self.vk.take_dirty();
        let hub_dirty = self.hub.take_dirty();
        let now = self.events.now();
        // Feed capacity edges to the zone-scoped admission pruner
        // before arming anything: the cycle a wakeup lands on must
        // already see them. Scheduler dirt (uncordon) has no shard
        // locality and re-opens every shard.
        if cluster_dirty {
            self.kueue.note_capacity_edges(&shard_edges);
        }
        if sched_dirty {
            self.kueue.note_capacity_edge_all();
        }
        if kueue_dirty || sched_dirty {
            self.admission_demand = true;
            self.arm_demand(KEY_ADMISSION, now, during);
        }
        if cluster_dirty {
            // Shard-hinted capacity edges arm per-shard one-shot
            // wakeups instead of the global admission timer: a
            // notebook churning in one zone never wakes placements
            // for the others (the cycle that pops prunes its searches
            // to the edged shards), yet every wakeup lands on exactly
            // the grid instant the un-sharded loop would have used —
            // and whichever timer pops first absorbs the rest, so
            // cycle counts are unchanged too.
            for s in shard_edges.iter() {
                self.armed_shards.insert(s);
                self.arm_demand(
                    KEY_SHARD_ADMISSION_BASE + s as TimerKey,
                    now,
                    during,
                );
            }
        }
        if vk_dirty {
            self.arm_demand(KEY_RECONCILE, now, during);
        }
        if hub_dirty {
            if let Some(d) = self.hub.next_cull_time() {
                self.arm_demand(KEY_CULL, d, during);
            }
        }
        // Service installation (or an SLO-relevant external mutation)
        // raises the serving edge; the tick itself keeps re-arming
        // level-triggered while services exist, so this only matters
        // for the first tick after an install mid-run.
        if self.serving.take_dirty() {
            self.arm_demand(KEY_SERVING, now, during);
        }
        // FL installation raises the FL edge; the tick itself keeps
        // re-arming level-triggered while rounds remain, so this only
        // matters for the first tick after an install mid-run.
        if self.fl.take_dirty() {
            self.arm_demand(KEY_FL, now, during);
        }
    }

    /// Arm `key`'s cycle at the earliest legal grid instant ≥ `target`.
    fn arm_demand(&mut self, key: TimerKey, target: Time, during: Option<u8>) {
        let (class, period) = self.cycle_meta(key);
        let now = self.events.now();
        // The current instant's slot is reusable only by cycles whose
        // class pops after the in-flight event (None ⇒ nothing is in
        // flight yet at this instant).
        let strict = match during {
            None => false,
            Some(current) => class <= current,
        };
        let at = grid_at(period, target.max(now), now, strict);
        self.arm_at(key, at);
    }

    fn cycle_meta(&self, key: TimerKey) -> (u8, f64) {
        match key {
            KEY_ADMISSION => (CLASS_ADMISSION, self.periods.admission),
            KEY_RECONCILE => (CLASS_RECONCILE, self.periods.reconcile),
            KEY_CULL => (CLASS_CULL, self.periods.cull),
            KEY_SERVING => (CLASS_SERVING, self.periods.serving),
            KEY_CHAOS => (CLASS_CHAOS, self.periods.chaos),
            KEY_FL => (CLASS_FL, self.periods.fl),
            k if k >= KEY_SHARD_ADMISSION_BASE => {
                // Per-shard admission wakeups share the admission
                // cycle's class and grid.
                (CLASS_ADMISSION, self.periods.admission)
            }
            _ => unreachable!("unknown cycle key {key}"),
        }
    }

    /// Keep-earliest keyed arming: an already-pending earlier wakeup
    /// absorbs the signal; a later one is moved up.
    fn arm_at(&mut self, key: TimerKey, at: Time) {
        match self.events.keyed_deadline(key) {
            Some(existing) if existing <= at => {}
            _ => {
                let (class, _) = self.cycle_meta(key);
                let ev = match key {
                    KEY_RECONCILE => Event::Reconcile,
                    KEY_SERVING => Event::ServingCycle,
                    KEY_CHAOS => Event::ChaosCycle,
                    KEY_FL => Event::FlCycle,
                    KEY_CULL => Event::CullPass,
                    // KEY_ADMISSION and every per-shard key.
                    _ => Event::AdmissionCycle,
                };
                self.events.cancel_keyed(key);
                self.events.schedule_keyed(key, at, class, ev);
            }
        }
    }

    /// Post-admission bookkeeping: local pods get a completion event,
    /// virtual pods go through interLink.
    fn on_admitted(&mut self, wl: WorkloadId, now: Time) {
        let w = self.kueue.workload(wl).unwrap();
        let pod = w.pod;
        let node = w.assigned_node.expect("admitted workload has a node");
        let is_virtual = self
            .cluster
            .node_by_id(node)
            .map(|n| n.virtual_node)
            .unwrap_or(false);
        if is_virtual {
            // Borrow the backend name straight out of the node record:
            // this runs once per admitted virtual workload, and the
            // burst scenarios admit tens of thousands.
            let backend = self
                .cluster
                .node_by_id(node)
                .unwrap()
                .backend
                .as_deref()
                .unwrap();
            let _ = self.vk.launch(&self.cluster, pod, backend, now);
        } else {
            let runtime = self.cluster.pod(pod).unwrap().spec.est_runtime_s;
            self.local_running.insert(pod, wl);
            self.events.after(runtime, Event::LocalJobDone(pod));
        }
    }

    /// Apply every fault due now, in plan order. The node-crash
    /// sequence is ordering-critical: cordon → drain (pods evicted,
    /// resources released) → Kueue fault-requeue (quota release needs
    /// the node present to classify it local) → respawn → remove_node
    /// (now empty, so the clean-detach fast path holds). The node
    /// object is parked in `ChaosRuntime::down` until its reboot
    /// re-adds it — under the same interned id, so pinned pods and
    /// recorded placements stay coherent.
    fn chaos_cycle(&mut self, now: Time) {
        let Some(mut chaos) = self.chaos.take() else { return };
        for ev in chaos.plan.due(now) {
            match ev.kind {
                FaultKind::NodeCrash { node } => {
                    if chaos.down.contains_key(&node)
                        || self.cluster.node_id(&node).is_none()
                    {
                        continue; // already down / never existed
                    }
                    self.scheduler.cordon(&node);
                    let evicted =
                        self.cluster.drain(&node).expect("node present");
                    chaos.n_node_failures += 1;
                    chaos.n_pods_evicted += evicted.len() as u64;
                    self.fault_requeue(&evicted, now, &chaos.policy);
                    let n = self
                        .cluster
                        .remove_node(&node)
                        .expect("drained node detaches cleanly");
                    self.trace.log(
                        now,
                        format!(
                            "chaos: {node} crashed, {} pods evicted",
                            evicted.len()
                        ),
                    );
                    chaos.down.insert(node, n);
                }
                FaultKind::NodeReboot { node } => {
                    if let Some(n) = chaos.down.remove(&node) {
                        self.cluster.add_node(n);
                        self.scheduler.uncordon(&node);
                        chaos.n_node_reboots += 1;
                        self.trace
                            .log(now, format!("chaos: {node} rebooted"));
                    }
                }
                FaultKind::GpuFail { node, model } => {
                    // A device on a down node fails silently (the crash
                    // already evicted everything); same for a model the
                    // node never had.
                    if let Ok(evicted) =
                        self.cluster.fail_gpu_device(&node, model)
                    {
                        chaos.n_gpu_failures += 1;
                        chaos.n_pods_evicted += evicted.len() as u64;
                        self.fault_requeue(&evicted, now, &chaos.policy);
                        self.trace.log(
                            now,
                            format!(
                                "chaos: {model} device failed on {node}, \
                                 {} pods evicted",
                                evicted.len()
                            ),
                        );
                    }
                }
                FaultKind::SiteOutage { .. } => {
                    // The window was installed on the SiteModel at
                    // install_chaos time; the event only counts.
                    chaos.n_site_outages += 1;
                }
            }
        }
        self.chaos = Some(chaos);
    }

    /// Route fault-evicted pods back through Kueue: bounded-backoff
    /// requeue (or terminal-Failed past the budget), then respawn fresh
    /// pods for the survivors. Pods with no Kueue workload — notebooks,
    /// directly-bound fillers — stay Evicted; their owners (hub
    /// sessions, the scenario) handle them.
    fn fault_requeue(
        &mut self,
        pods: &[PodId],
        now: Time,
        policy: &RecoveryPolicy,
    ) {
        if pods.is_empty() {
            return;
        }
        let _ = self.kueue.requeue_faulted(
            &mut self.cluster,
            pods,
            now,
            policy.backoff_base_s,
            policy.retry_budget,
        );
        self.kueue.respawn_evicted_pods(&mut self.cluster);
    }

    /// One serving tick: reconcile each service's replica set against
    /// Kueue, advance its trace/batcher, and execute the scale decision
    /// — replicas are ordinary batch slice pods submitted through the
    /// service's ClusterQueue, so they compete under the cohort quota
    /// tree and placement goes through the one scheduler (byte-identical
    /// across placement modes like any other pod).
    fn serving_cycle(&mut self, now: Time) {
        let now_s = now as u64;
        for i in 0..self.serving.services.len() {
            let (running, _live) =
                self.serving.services[i].reconcile(&self.kueue);
            let (_stats, action) =
                self.serving.services[i].tick(now_s, running);
            match action {
                ScaleAction::Hold => {}
                ScaleAction::Up(n) => {
                    let (shape, queue, owner) = {
                        let s = &self.serving.services[i].spec;
                        (
                            s.replica_shape.clone(),
                            s.queue.clone(),
                            format!("svc-{}", s.name),
                        )
                    };
                    for _ in 0..n {
                        let spec = crate::cluster::PodSpec::batch(
                            &owner,
                            shape.clone(),
                            "triton-inference-server",
                        )
                        .with_runtime(30.0 * 24.0 * 3600.0);
                        let pod = self.cluster.create_pod(spec);
                        match self.kueue.submit(pod, &queue, &owner, false, now)
                        {
                            Ok(wid) => {
                                self.serving.services[i].replicas.push(wid);
                                self.serving.services[i].spawned += 1;
                            }
                            Err(_) => {
                                let _ = self.cluster.delete_pod(pod);
                            }
                        }
                    }
                }
                ScaleAction::Down(n) => {
                    for _ in 0..n {
                        // Junior-most *admitted* replica; queued ones
                        // stay (they are the repair rule's claim on
                        // future quota, not capacity to shed).
                        let pos = {
                            let svc = &self.serving.services[i];
                            svc.replicas.iter().rposition(|&wid| {
                                self.kueue
                                    .workload(wid)
                                    .map(|w| {
                                        w.state == WorkloadState::Admitted
                                    })
                                    .unwrap_or(false)
                            })
                        };
                        let Some(pos) = pos else { break };
                        let wid = self.serving.services[i].replicas.remove(pos);
                        let pod = self.kueue.workload(wid).unwrap().pod;
                        if self.cluster.pod(pod).map(|p| p.phase)
                            == Some(PodPhase::Running)
                        {
                            let _ = self.cluster.complete(pod);
                        }
                        let _ = self.kueue.finish(&self.cluster, wid, true, now);
                        self.local_running.remove(&pod);
                        self.serving.services[i].retired += 1;
                    }
                }
            }
        }
    }

    /// One FL tick: derive per-site outage flags from the interLink
    /// site models, advance the round state machine one phase-step, and
    /// execute its actions — aggregator/trainer pods are ordinary batch
    /// pods submitted through the job's ClusterQueue, so they borrow
    /// idle cohort quota and get reclaimed junior-first exactly like
    /// serving replicas. Trainers are offload pods pinned to their
    /// site's virtual node (`vk-<site>`) with an `est_runtime` covering
    /// the site's full straggler tail, so the reconcile path finishes
    /// them naturally; only the local aggregator is retired by hand at
    /// Commit (the serving submit/retire idiom).
    fn fl_cycle(&mut self, now: Time) {
        let now_s = now as u64;
        let outages: Vec<bool> = match self.fl.spec.as_ref() {
            None => return,
            Some(spec) => spec
                .sites
                .iter()
                .map(|s| {
                    self.vk
                        .site(s)
                        .map(|m| m.in_outage(now))
                        .unwrap_or(false)
                })
                .collect(),
        };
        let actions = self.fl.tick(now_s, &outages);
        for action in actions {
            match action {
                FlAction::BeginRound { round } => {
                    let spec = self.fl.spec.as_ref().unwrap();
                    self.trace.log(
                        now,
                        format!(
                            "fl: {} round {round} selects {} clients",
                            spec.name,
                            spec.total_selected(round)
                        ),
                    );
                    // Per-round dev-loop session churn: the coordinator
                    // operator watches each round from a notebook. A
                    // failed spawn (no capacity) degrades to no session
                    // — never a wedged round.
                    if let Ok(sid) =
                        self.spawn_notebook("fl-coordinator", "cpu-small", now)
                    {
                        self.fl.dev_session = Some(sid);
                    }
                }
                FlAction::SpawnAggregator { round } => {
                    let (name, queue, cpu_m) = {
                        let spec = self.fl.spec.as_ref().unwrap();
                        (
                            spec.name.clone(),
                            spec.queue.clone(),
                            spec.aggregator_cpu_m,
                        )
                    };
                    let owner = format!("fl-{name}");
                    let spec = crate::cluster::PodSpec::batch(
                        &owner,
                        crate::cluster::Resources::cpu_mem(cpu_m, 4 * GIB),
                        "fl-aggregator",
                    )
                    .with_runtime(30.0 * 24.0 * 3600.0);
                    let pod = self.cluster.create_pod(spec);
                    match self.kueue.submit(pod, &queue, &owner, false, now) {
                        Ok(wid) => {
                            self.fl.aggregators.push(wid);
                            self.fl.spawned += 1;
                        }
                        Err(_) => {
                            let _ = self.cluster.delete_pod(pod);
                        }
                    }
                    let _ = round;
                }
                FlAction::SpawnTrainers { round, sites } => {
                    for site_idx in sites {
                        let (name, queue, cpu_m, site, runtime) = {
                            let spec = self.fl.spec.as_ref().unwrap();
                            (
                                spec.name.clone(),
                                spec.queue.clone(),
                                spec.trainer_cpu_m,
                                spec.sites[site_idx].clone(),
                                (spec.distribute_s
                                    + spec.full_report_s(round, site_idx))
                                    as f64,
                            )
                        };
                        let owner = format!("fl-{name}");
                        let mut spec = crate::cluster::PodSpec::batch(
                            &owner,
                            crate::cluster::Resources::cpu_mem(cpu_m, 2 * GIB),
                            "fl-trainer",
                        )
                        .with_runtime(runtime);
                        spec.offload_compatible = true;
                        spec.tolerations.push("interlink.virtual-node".into());
                        spec.tolerations.push("interlink.no-fuse".into());
                        // Pin the trainer to the site's virtual node:
                        // training capacity lands where the cohort is.
                        spec.node_selector = Some(format!("vk-{site}"));
                        let pod = self.cluster.create_pod(spec);
                        match self.kueue.submit(pod, &queue, &owner, true, now)
                        {
                            Ok(_) => self.fl.spawned += 1,
                            Err(_) => {
                                let _ = self.cluster.delete_pod(pod);
                            }
                        }
                    }
                }
                FlAction::CompleteRound { round } => {
                    let rec = *self
                        .fl
                        .records
                        .last()
                        .expect("a committed round has a record");
                    self.trace.log(
                        now,
                        format!(
                            "fl: round {round} committed: {} reported, \
                             {} dropped, {} late in {} s",
                            rec.reported, rec.dropped, rec.late, rec.duration_s
                        ),
                    );
                    self.fl.retire_current_round();
                    if let Some(sid) = self.fl.dev_session.take() {
                        let _ = self.end_session(sid);
                    }
                }
            }
        }
        self.retire_fl_aggregators(now);
    }

    /// Retire committed rounds' aggregator pods: Admitted ones finish
    /// now (freeing their quota); a quota-evicted aggregator still
    /// sitting in the queue is pushed back and retired on a later tick
    /// once re-admitted — `Kueue::finish` only accepts Admitted
    /// workloads.
    fn retire_fl_aggregators(&mut self, now: Time) {
        let pending = self.fl.take_retiring();
        if pending.is_empty() {
            return;
        }
        for wid in pending {
            match self.kueue.workload(wid).map(|w| (w.state, w.pod)) {
                Some((WorkloadState::Admitted, pod)) => {
                    if self.cluster.pod(pod).map(|p| p.phase)
                        == Some(PodPhase::Running)
                    {
                        let _ = self.cluster.complete(pod);
                    }
                    let _ = self.kueue.finish(&self.cluster, wid, true, now);
                    self.local_running.remove(&pod);
                    self.fl.retired += 1;
                }
                Some((WorkloadState::Queued, _)) => {
                    self.fl.retiring.push(wid);
                }
                _ => {
                    self.fl.retired += 1;
                }
            }
        }
    }

    /// Drive the platform until `deadline` (virtual seconds).
    pub fn run_until(&mut self, deadline: Time) {
        if self.periods.mode == LoopMode::Reactive {
            // External mutations (spawns, submits, direct binds) since
            // the last event raise dirty edges; convert them before
            // draining so their wakeups can land on this instant's
            // still-unpopped grid slot, exactly like a polling cycle.
            self.react(None);
        }
        // Pull the event queue out so handle() can schedule into it.
        let mut events = std::mem::take(&mut self.events);
        events.run_until(deadline, |q, t, ev| {
            // Temporarily give the queue back for re-arming.
            std::mem::swap(&mut self.events, q);
            self.handle(t, ev);
            std::mem::swap(&mut self.events, q);
        });
        self.events = events;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuModel;

    fn platform() -> Platform {
        let mut p = Platform::ai_infn(42);
        p.iam.register("rosa", "Rosa", &["lhcb-flashsim"]);
        p
    }

    fn reactive_platform() -> Platform {
        let mut p = platform();
        p.periods.mode = LoopMode::Reactive;
        p
    }

    #[test]
    fn spawn_and_end_session_roundtrip() {
        let mut p = platform();
        let sid = p.spawn_notebook("rosa", "gpu-nvidia-a100", 0.0).unwrap();
        assert_eq!(p.hub.active_count(), 1);
        assert_eq!(p.cluster.running_pods(), 1);
        let name = p.hub.session(sid).unwrap().name.clone();
        assert!(p.ephemeral.volume(&name).is_some());
        p.end_session(sid).unwrap();
        assert_eq!(p.hub.active_count(), 0);
        assert_eq!(p.cluster.running_pods(), 0);
        assert!(p.ephemeral.volume(&name).is_none());
        p.cluster.check_accounting().unwrap();
    }

    fn polling_platform() -> Platform {
        let mut p = platform();
        p.periods.mode = LoopMode::Polling;
        p
    }

    #[test]
    fn periodic_loops_rearm() {
        let mut p = polling_platform();
        p.run_until(601.0);
        // scrape every 60 s → ≥10 scrapes ingested series
        assert!(p.tsdb.samples_ingested > 50);
        assert!(p.events.processed() > 20);
        assert!(p.cycles.admission > 100, "5 s admission grid over 601 s");
        assert_eq!(p.cycles.total() , p.events.processed());
    }

    #[test]
    fn reactive_idle_platform_runs_sweeps_not_polls() {
        let mut p = reactive_platform();
        p.run_until(601.0);
        // Observability stays periodic...
        assert!(p.tsdb.samples_ingested > 50);
        assert!(p.cycles.scrape >= 10);
        // ...but with no demand the controller cycles only prime at
        // t=0 and sweep at t=600 (default sweep).
        assert_eq!(p.cycles.admission, 2, "t=0 prime + one 600 s sweep");
        assert_eq!(p.cycles.reconcile, 2);
        // 11 scrapes + 3 accountings + 2 sweeps each of the three
        // demand cycles = 20, vs the polling loop's ~198.
        assert!(
            p.cycles.total() <= 20,
            "idle reactive loop must not poll: {:?}",
            p.cycles
        );
    }

    #[test]
    fn notebook_spawn_evicts_batch_under_contention() {
        let mut p = platform();
        // Saturate every A100 with batch jobs (5 A100s total).
        for i in 0..5 {
            let mut spec = crate::cluster::PodSpec::batch(
                "batch-user",
                crate::cluster::Resources {
                    gpus: 1,
                    gpu_model: Some(GpuModel::A100),
                    ..crate::cluster::Resources::cpu_mem(1000, GIB)
                },
                "train",
            );
            spec.est_runtime_s = 100_000.0;
            let pod = p.cluster.create_pod(spec);
            p.kueue
                .submit(pod, "local-batch", "batch-user", false, 0.0)
                .unwrap();
            let _ = i;
        }
        p.run_until(10.0); // admission cycle runs
        assert_eq!(p.cluster.running_pods(), 5);
        let sid = p.spawn_notebook("rosa", "gpu-nvidia-a100", 10.0).unwrap();
        assert_eq!(p.hub.active_count(), 1);
        assert!(p.kueue.n_evictions >= 1);
        // The evicted workload is requeued, not lost.
        assert!(p.kueue.pending_count() >= 1);
        let _ = sid;
        p.cluster.check_accounting().unwrap();
    }

    #[test]
    fn local_batch_completes_via_event() {
        let mut p = platform();
        let spec = crate::cluster::PodSpec::batch(
            "rosa",
            crate::cluster::Resources::flashsim_cpu(),
            "flashsim",
        )
        .with_runtime(120.0);
        let pod = p.cluster.create_pod(spec);
        let wl = p.kueue.submit(pod, "local-batch", "rosa", false, 0.0).unwrap();
        p.run_until(300.0);
        assert_eq!(p.cluster.pod(pod).unwrap().phase, PodPhase::Succeeded);
        assert_eq!(
            p.kueue.workload(wl).unwrap().state,
            WorkloadState::Finished
        );
    }

    /// The unit-scale edge/level equivalence check: the same workload
    /// through both loop modes must finish with identical admission
    /// decisions and timestamps, while the reactive mode runs strictly
    /// fewer controller cycles. (The scenario-scale golden CSVs live in
    /// `experiments::fed_stress` / `experiments::fig2`.)
    #[test]
    fn reactive_matches_polling_decisions_with_fewer_cycles() {
        let run = |mode: LoopMode| {
            let mut p = platform();
            p.periods.mode = mode;
            let mut wls = Vec::new();
            for i in 0..30 {
                let mut spec = crate::cluster::PodSpec::batch(
                    "rosa",
                    crate::cluster::Resources::flashsim_cpu(),
                    "fs",
                )
                .with_runtime(200.0 + 17.0 * i as f64);
                spec.offload_compatible = true;
                spec.tolerations.push("interlink.virtual-node".into());
                let pod = p.cluster.create_pod(spec);
                wls.push(
                    p.kueue.submit(pod, "local-batch", "rosa", true, 0.0).unwrap(),
                );
            }
            p.run_until(1800.0);
            let decisions: Vec<_> = wls
                .iter()
                .map(|&wl| {
                    let w = p.kueue.workload(wl).unwrap();
                    (
                        w.state,
                        w.admitted_at,
                        w.finished_at,
                        w.assigned_node.map(|n| p.cluster.name_of(n).to_string()),
                    )
                })
                .collect();
            (
                decisions,
                p.kueue.n_admitted_local,
                p.kueue.n_admitted_virtual,
                p.tsdb.samples_ingested,
                p.cycles,
                p.events.processed(),
            )
        };
        let (pd, pl, pv, ps, pc, pe) = run(LoopMode::Polling);
        let (rd, rl, rv, rs, rc, re) = run(LoopMode::Reactive);
        assert_eq!(pd, rd, "admission decisions diverged across loop modes");
        assert_eq!((pl, pv), (rl, rv));
        assert_eq!(ps, rs, "scrapes observe identical state");
        assert!(
            rc.total() < pc.total(),
            "reactive ran {} cycles, polling {}",
            rc.total(),
            pc.total()
        );
        assert!(re < pe, "reactive processed {re} events, polling {pe}");
    }

    /// The borrow/reclaim cascade through the event loop: a borrower
    /// burst followed by an owner wave must resolve identically under
    /// both loop modes — the reclaim evictions inside an admission
    /// cycle raise the Kueue + cluster dirty edges that re-arm the
    /// next cycle, so the reactive loop needs no extra polling to
    /// finish the cascade.
    #[test]
    fn cohort_reclaim_cascade_matches_across_loop_modes() {
        use crate::kueue::{ClusterQueue, QuotaVec};
        let run = |mode: LoopMode| {
            let mut p = Platform::local_only(9);
            p.periods.mode = mode;
            // The §2 farm's workers hold 448k CPU; carve a cohort out
            // of it: an owner entitled to 200k and a small borrower.
            p.kueue.add_queue(
                ClusterQueue::with_nominal("owner", QuotaVec::cpu(200_000))
                    .in_cohort("tenants"),
            );
            p.kueue.add_queue(
                ClusterQueue::with_nominal("borrower", QuotaVec::cpu(40_000))
                    .in_cohort("tenants"),
            );
            let job = |p: &mut Platform| {
                p.cluster.create_pod(
                    crate::cluster::PodSpec::batch(
                        "u",
                        crate::cluster::Resources::cpu_mem(20_000, GIB),
                        "job",
                    )
                    .with_runtime(100_000.0),
                )
            };
            // Borrower burst at t=0: 12 × 20k = 240k (40k nominal +
            // 200k borrowed — the whole owner quota).
            let mut borrower_wls = Vec::new();
            for _ in 0..12 {
                let pod = job(&mut p);
                borrower_wls
                    .push(p.kueue.submit(pod, "borrower", "u", false, 0.0).unwrap());
            }
            p.run_until(60.0);
            let peak_borrowed = p.kueue.queue("borrower").unwrap().borrowed();
            // Owner wave at t=60: 10 × 20k = its full nominal quota.
            let mut owner_wls = Vec::new();
            for _ in 0..10 {
                let pod = job(&mut p);
                owner_wls
                    .push(p.kueue.submit(pod, "owner", "u", false, 60.0).unwrap());
            }
            p.run_until(300.0);
            let states: Vec<_> = borrower_wls
                .iter()
                .chain(&owner_wls)
                .map(|&w| {
                    let w = p.kueue.workload(w).unwrap();
                    (w.state, w.admitted_at, w.requeues, w.preempted_by)
                })
                .collect();
            p.kueue.check_cohort_invariants().unwrap();
            p.cluster.check_accounting().unwrap();
            (
                peak_borrowed,
                p.kueue.queue("owner").unwrap().used,
                p.kueue.queue("borrower").unwrap().used,
                p.kueue.n_reclaim_evictions,
                states,
                p.cycles,
            )
        };
        let (pb, po, pbw, pr, ps, pc) = run(LoopMode::Polling);
        let (rb, ro, rbw, rr, rs, rc) = run(LoopMode::Reactive);
        assert_eq!(pb, QuotaVec::cpu(200_000), "burst absorbs the owner quota");
        assert_eq!(po, QuotaVec::cpu(200_000), "owner restored to nominal");
        assert_eq!(pbw, QuotaVec::cpu(40_000), "borrower back at nominal");
        assert!(pr >= 10, "the owner wave reclaimed");
        assert_eq!((pb, po, pbw, pr), (rb, ro, rbw, rr));
        assert_eq!(ps, rs, "workload outcomes diverged across loop modes");
        assert!(
            rc.total() < pc.total(),
            "reactive cascade must not poll: {} vs {}",
            rc.total(),
            pc.total()
        );
    }

    #[test]
    fn reactive_session_ends_and_culls_on_schedule() {
        let mut p = reactive_platform();
        let sid = p.spawn_notebook("rosa", "cpu-small", 0.0).unwrap();
        p.events.at(900.0, Event::SessionEnds(sid));
        p.run_until(1000.0);
        assert_eq!(p.hub.active_count(), 0);
        assert_eq!(p.cluster.running_pods(), 0);
        // And the idle culler still works end-to-end on the demand
        // path: a second session left idle past cull_after.
        p.iam.register("mallory", "Mallory", &[]);
        let s2 = p.spawn_notebook("mallory", "cpu-small", 1000.0).unwrap();
        let _ = s2;
        p.run_until(1000.0 + p.hub.cull_after + 1300.0);
        assert_eq!(p.hub.active_count(), 0, "idle session culled reactively");
        p.cluster.check_accounting().unwrap();
    }

    #[test]
    fn determinism_same_seed_same_state() {
        let run = |seed| {
            let mut p = Platform::ai_infn(seed);
            p.iam.register("rosa", "Rosa", &["lhcb-flashsim"]);
            for i in 0..50 {
                let spec = crate::cluster::PodSpec::batch(
                    "rosa",
                    crate::cluster::Resources::flashsim_cpu(),
                    "fs",
                )
                .with_runtime(300.0 + i as f64);
                let mut spec = spec;
                spec.offload_compatible = true;
                spec.tolerations.push("interlink.virtual-node".into());
                let pod = p.cluster.create_pod(spec);
                p.kueue.submit(pod, "local-batch", "rosa", true, 0.0).unwrap();
            }
            p.run_until(3600.0);
            (
                p.events.processed(),
                p.kueue.n_admitted_local,
                p.kueue.n_admitted_virtual,
                p.tsdb.samples_ingested,
            )
        };
        assert_eq!(run(7), run(7));
    }

    #[test]
    fn node_crash_requeues_with_backoff_and_reboot_restores() {
        use crate::chaos::{FaultEvent, FaultKind, FaultPlan};
        let mut p = Platform::local_only(1);
        let spec = crate::cluster::PodSpec::batch(
            "rosa",
            crate::cluster::Resources::flashsim_cpu(),
            "fs",
        )
        .with_runtime(10_000.0);
        let pod = p.cluster.create_pod(spec);
        let wl = p.kueue.submit(pod, "local-batch", "rosa", false, 0.0).unwrap();
        p.run_until(10.0);
        let victim = {
            let w = p.kueue.workload(wl).unwrap();
            assert_eq!(w.state, WorkloadState::Admitted);
            p.cluster.name_of(w.assigned_node.unwrap()).to_string()
        };
        p.install_chaos(
            FaultPlan::new(vec![
                FaultEvent {
                    at: 20.0,
                    kind: FaultKind::NodeCrash { node: victim.clone() },
                },
                FaultEvent {
                    at: 60.0,
                    kind: FaultKind::NodeReboot { node: victim.clone() },
                },
            ]),
            RecoveryPolicy::default(),
        );
        p.run_until(55.0);
        // Crashed at 20: the workload backed off to 20+10·2⁰ = 30 and
        // readmitted at exactly the first admission instant ≥ 30.
        {
            let w = p.kueue.workload(wl).unwrap();
            assert_eq!(w.state, WorkloadState::Admitted);
            assert_eq!(w.fault_requeues, 1);
            assert_eq!(w.admitted_at, Some(30.0), "backoff lands on the grid");
        }
        assert!(p.cluster.node_id(&victim).is_none(), "node is down");
        assert_eq!(p.kueue.n_fault_evictions, 1);
        p.run_until(120.0);
        assert!(p.cluster.node_id(&victim).is_some(), "node rebooted");
        let chaos = p.chaos.as_ref().unwrap();
        assert_eq!(chaos.n_node_failures, 1);
        assert_eq!(chaos.n_node_reboots, 1);
        assert_eq!(chaos.n_pods_evicted, 1);
        assert!(chaos.plan.is_done());
        assert_eq!(p.kueue.n_fault_recoveries, 1);
        assert_eq!(p.kueue.fault_recovery_max_s, 10.0);
        p.cluster.check_accounting().unwrap();
        p.kueue.check_cohort_invariants().unwrap();
    }

    /// The chaos acceptance contract at unit scale: the same fault
    /// plan through both loop modes yields byte-identical workload
    /// outcomes, fault counters and recovery stats, with the reactive
    /// loop still running fewer cycles. (Scenario scale lives in
    /// `experiments::chaos_stress`.)
    #[test]
    fn chaos_recovery_is_byte_identical_across_loop_modes() {
        use crate::chaos::FaultPlan;
        let run = |mode: LoopMode| {
            let mut p = Platform::local_only(9);
            p.periods.mode = mode;
            let mut wls = Vec::new();
            for i in 0..8 {
                let spec = crate::cluster::PodSpec::batch(
                    "rosa",
                    crate::cluster::Resources::flashsim_cpu(),
                    "fs",
                )
                .with_runtime(400.0 + 23.0 * i as f64);
                let pod = p.cluster.create_pod(spec);
                wls.push(
                    p.kueue
                        .submit(pod, "local-batch", "rosa", false, 0.0)
                        .unwrap(),
                );
            }
            let workers: Vec<String> =
                (1..=4).map(|i| format!("server-{i}")).collect();
            p.install_chaos(
                FaultPlan::new(FaultPlan::rolling_crashes(
                    5, &workers, 20.0, 10.0, 2, 30.0,
                )),
                RecoveryPolicy::default(),
            );
            p.run_until(900.0);
            let outcomes: Vec<_> = wls
                .iter()
                .map(|&wl| {
                    let w = p.kueue.workload(wl).unwrap();
                    (
                        w.state,
                        w.admitted_at,
                        w.finished_at,
                        w.fault_requeues,
                        w.requeues,
                    )
                })
                .collect();
            p.cluster.check_accounting().unwrap();
            p.kueue.check_cohort_invariants().unwrap();
            let chaos = p.chaos.as_ref().unwrap();
            (
                outcomes,
                p.kueue.n_fault_evictions,
                p.kueue.n_fault_recoveries,
                p.kueue.fault_recovery_max_s,
                (chaos.n_node_failures, chaos.n_node_reboots),
                chaos.n_pods_evicted,
                p.cycles,
            )
        };
        let (po, pe, pr, pm, pn, pp, pc) = run(LoopMode::Polling);
        let (ro, re, rr, rm, rn, rp, rc) = run(LoopMode::Reactive);
        assert_eq!(po, ro, "workload outcomes diverged under faults");
        assert_eq!((pe, pr, pm, pn, pp), (re, rr, rm, rn, rp));
        assert_eq!(pn, (2, 2), "both crashes applied, both reboots");
        assert!(
            po.iter().all(|(s, ..)| *s == WorkloadState::Finished),
            "no workload lost to the fault plan: {po:?}"
        );
        assert_eq!(pc.chaos, rc.chaos, "chaos cycles are keyed, not polled");
        assert!(
            rc.total() < pc.total(),
            "reactive under chaos must not poll: {} vs {}",
            rc.total(),
            pc.total()
        );
    }

    /// The FL acceptance contract at unit scale: the same FL job
    /// through both loop modes commits every round with byte-identical
    /// round records and counters. The FL tick itself is
    /// level-triggered while rounds remain, so its cycle count matches
    /// exactly across modes — yet the reactive loop still runs fewer
    /// cycles overall. (Scenario scale lives in
    /// `experiments::fl_rounds`.)
    #[test]
    fn fl_rounds_commit_identically_across_loop_modes() {
        use crate::kueue::{ClusterQueue, QuotaVec};
        let run = |mode: LoopMode| {
            let mut p = Platform::ai_infn(11);
            p.periods.mode = mode;
            p.kueue.add_queue(
                ClusterQueue::with_nominal("fl", QuotaVec::cpu(64_000))
                    .in_cohort("tenants"),
            );
            let spec = FlSpec::new(
                "mnist",
                &[
                    ("infncnaf", 500_000),
                    ("leonardo", 400_000),
                    ("recas", 100_000),
                ],
                3,
                120_000,
                13,
            )
            .with_shape(10, 10, 120);
            p.install_fl(spec);
            p.run_until(1200.0);
            p.cluster.check_accounting().unwrap();
            p.kueue.check_cohort_invariants().unwrap();
            (
                p.fl.records.clone(),
                p.fl.rounds_committed,
                (
                    p.fl.clients_selected_total,
                    p.fl.updates_received_total,
                    p.fl.dropouts_total,
                    p.fl.late_total,
                ),
                (p.fl.spawned, p.fl.retired),
                p.cycles,
            )
        };
        let (prec, pn, ptot, ppods, pc) = run(LoopMode::Polling);
        let (rrec, rn, rtot, rpods, rc) = run(LoopMode::Reactive);
        assert_eq!(pn, 3, "every round commits");
        assert_eq!(
            ptot.0,
            ptot.1 + ptot.2 + ptot.3,
            "client conservation across the whole run"
        );
        assert!(ppods.0 >= 3 * 4, "aggregator + 3 trainers per round");
        assert_eq!(ppods.0.saturating_sub(ppods.1), 3 * 3, "aggregators retired");
        assert_eq!(prec, rrec, "round records diverged across loop modes");
        assert_eq!((pn, ptot, ppods), (rn, rtot, rpods));
        assert_eq!(pc.fl, rc.fl, "FL is level-triggered: cycle counts match");
        assert!(
            rc.total() < pc.total(),
            "reactive under FL must not poll: {} vs {}",
            rc.total(),
            pc.total()
        );
    }

    #[test]
    fn reactive_determinism_same_seed_same_state() {
        let run = |seed| {
            let mut p = Platform::ai_infn(seed);
            p.periods.mode = LoopMode::Reactive;
            p.iam.register("rosa", "Rosa", &["lhcb-flashsim"]);
            for i in 0..50 {
                let mut spec = crate::cluster::PodSpec::batch(
                    "rosa",
                    crate::cluster::Resources::flashsim_cpu(),
                    "fs",
                )
                .with_runtime(300.0 + i as f64);
                spec.offload_compatible = true;
                spec.tolerations.push("interlink.virtual-node".into());
                let pod = p.cluster.create_pod(spec);
                p.kueue.submit(pod, "local-batch", "rosa", true, 0.0).unwrap();
            }
            p.run_until(3600.0);
            (
                p.events.processed(),
                p.cycles,
                p.kueue.n_admitted_local,
                p.kueue.n_admitted_virtual,
                p.tsdb.samples_ingested,
            )
        };
        assert_eq!(run(7), run(7));
    }
}
