//! The platform coordinator: composes every subsystem into the running
//! AI_INFN platform and drives scenarios on the discrete-event engine.
//!
//! This is the Layer-3 "leader": the event loop owns the cluster state,
//! routes hub spawns (with the §4 Kueue contention path), runs Kueue
//! admission cycles, reconciles the virtual-node controller against the
//! site plugins, scrapes monitoring, and updates accounting — the same
//! loop the real platform distributes across controllers.

use crate::cluster::{
    ai_infn_farm, Cluster, PodId, PodPhase, ScheduleError, Scheduler,
    ScoringPolicy,
};
use crate::hub::{Hub, HubError};
use crate::iam::Iam;
use crate::kueue::{Kueue, WorkloadId, WorkloadState};
use crate::monitoring::{scrape_all, Accounting, Tsdb};
use crate::offload::{plugins, VirtualNodeController};
use crate::sim::{EventQueue, Time, Trace};
use crate::storage::ephemeral::EphemeralManager;
use crate::storage::nfs::NfsServer;
use crate::util::bytes::GIB;
use crate::util::rng::Rng;
use crate::vkd::Vkd;

/// Platform event loop payloads.
#[derive(Debug)]
pub enum Event {
    /// Kueue admission pass.
    AdmissionCycle,
    /// Virtual-kubelet reconcile (site ticks + status sync).
    Reconcile,
    /// Prometheus scrape.
    Scrape,
    /// Accounting aggregation.
    AccountingUpdate,
    /// A locally-running batch pod finishes.
    LocalJobDone(PodId),
    /// A notebook session ends (user closes / culler).
    SessionEnds(String),
    /// Idle-culler pass.
    CullPass,
}

/// Tunable loop periods (seconds).
#[derive(Clone, Debug)]
pub struct Periods {
    pub admission: f64,
    pub reconcile: f64,
    pub scrape: f64,
    pub accounting: f64,
    pub cull: f64,
}

impl Default for Periods {
    fn default() -> Self {
        Periods {
            admission: 5.0,
            reconcile: 10.0,
            scrape: 60.0,
            accounting: 300.0,
            cull: 600.0,
        }
    }
}

/// The composed platform.
pub struct Platform {
    pub cluster: Cluster,
    pub scheduler: Scheduler,
    pub iam: Iam,
    pub hub: Hub,
    pub kueue: Kueue,
    pub vkd: Vkd,
    pub vk: VirtualNodeController,
    pub nfs: NfsServer,
    pub ephemeral: EphemeralManager,
    pub tsdb: Tsdb,
    pub accounting: Accounting,
    pub events: EventQueue<Event>,
    pub trace: Trace,
    pub rng: Rng,
    pub periods: Periods,
    /// Workloads whose local pods have a scheduled completion event.
    local_running: std::collections::BTreeMap<PodId, WorkloadId>,
}

impl std::fmt::Debug for Platform {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Platform")
            .field("now", &self.events.now())
            .field("nodes", &self.cluster.nodes().count())
            .field("pods_running", &self.cluster.running_pods())
            .finish()
    }
}

impl Platform {
    /// The paper's platform: §2 farm + §4 federated sites.
    pub fn ai_infn(seed: u64) -> Self {
        let mut cluster = ai_infn_farm();
        let mut vk = VirtualNodeController::new();
        for site in plugins::fig2_testbed(seed) {
            vk.register_site(&mut cluster, site);
        }
        Self::with_parts(cluster, vk, seed)
    }

    /// Local-only platform (no federation) — the MOT1 baseline.
    pub fn local_only(seed: u64) -> Self {
        Self::with_parts(ai_infn_farm(), VirtualNodeController::new(), seed)
    }

    /// A platform over an arbitrary cluster + federation — the
    /// federation stress scenario builds its scaled farm through this.
    pub fn custom(
        cluster: Cluster,
        vk: VirtualNodeController,
        seed: u64,
    ) -> Self {
        Self::with_parts(cluster, vk, seed)
    }

    fn with_parts(
        cluster: Cluster,
        vk: VirtualNodeController,
        seed: u64,
    ) -> Self {
        let mut ephemeral = EphemeralManager::new();
        for node in cluster.nodes().filter(|n| n.capacity.nvme > 0) {
            ephemeral.register_node(&node.name, node.capacity.nvme);
        }
        let mut p = Platform {
            cluster,
            scheduler: Scheduler::new(),
            iam: Iam::new(seed),
            hub: Hub::new(),
            kueue: Kueue::new(),
            vkd: Vkd::new(),
            vk,
            nfs: NfsServer::new(100 * GIB),
            ephemeral,
            tsdb: Tsdb::new(),
            accounting: Accounting::new(3600.0),
            events: EventQueue::new(),
            trace: Trace::new(10_000, false),
            rng: Rng::new(seed),
            periods: Periods::default(),
            local_running: Default::default(),
        };
        // Prime the periodic loops.
        p.events.at(0.0, Event::AdmissionCycle);
        p.events.at(0.0, Event::Reconcile);
        p.events.at(0.0, Event::Scrape);
        p.events.at(0.0, Event::AccountingUpdate);
        p.events.at(0.0, Event::CullPass);
        p
    }

    pub fn now(&self) -> Time {
        self.events.now()
    }

    /// Spawn a notebook with the §4 contention path: if the pod cannot
    /// be placed, Kueue evicts opportunistic batch to make room.
    pub fn spawn_notebook(
        &mut self,
        subject: &str,
        profile: &str,
        now: Time,
    ) -> Result<String, HubError> {
        let token = self
            .iam
            .issue_token(subject, now)
            .map_err(|e| HubError::Auth(format!("{e:?}")))?;
        let cluster = &mut self.cluster;
        let sid = self.hub.begin_spawn(
            &self.iam,
            &token,
            profile,
            &mut self.nfs,
            now,
            |spec| cluster.create_pod(spec),
        )?;
        let pod = self.hub.session(&sid).unwrap().pod;
        match self.scheduler.schedule(&mut self.cluster, pod, ScoringPolicy::BinPack)
        {
            Ok(node) => {
                let msg =
                    format!("spawn {sid} on {}", self.cluster.name_of(node));
                self.trace.log(now, msg);
            }
            Err(ScheduleError::NoCapacity) => {
                // §4: batch is "immediately evicted in case new notebook
                // instances are spawned".
                match self.kueue.make_room_for_notebook(
                    &mut self.cluster,
                    &self.scheduler,
                    pod,
                ) {
                    Ok((node, evicted)) => {
                        let msg = format!(
                            "spawn {sid} on {} after evicting {} batch pods",
                            self.cluster.name_of(node),
                            evicted.len()
                        );
                        self.trace.log(now, msg);
                        self.kueue.respawn_evicted_pods(&mut self.cluster);
                    }
                    Err(e) => {
                        // Roll the session back.
                        let _ = self.hub.stop(&sid, &mut self.nfs);
                        let _ = self.cluster.delete_pod(pod);
                        return Err(HubError::Auth(format!(
                            "no capacity and no preemption plan: {e}"
                        )));
                    }
                }
            }
            Err(ScheduleError::Unschedulable(e)) => {
                let _ = self.hub.stop(&sid, &mut self.nfs);
                let _ = self.cluster.delete_pod(pod);
                return Err(HubError::Auth(format!("unschedulable: {e}")));
            }
        }
        self.hub.activate(&sid, now).unwrap();
        self.accounting.record_session(subject, now);
        // Ephemeral scratch volume on the session's node (the pool map
        // is name-keyed — a boundary structure, so resolve the handle).
        let node = self.cluster.pod(pod).unwrap().node.unwrap();
        let node_name = self.cluster.name_of(node);
        if self.ephemeral.pool_free(node_name).unwrap_or(0) > 100 * GIB {
            let _ = self.ephemeral.create_volume(&sid, node_name, 100 * GIB);
        }
        Ok(sid)
    }

    /// End a session: stop in hub, free pod, destroy scratch.
    pub fn end_session(&mut self, sid: &str) -> Result<(), String> {
        let pod = self
            .hub
            .stop(sid, &mut self.nfs)
            .map_err(|e| format!("{e:?}"))?;
        if self.cluster.pod(pod).map(|p| p.phase) == Some(PodPhase::Running) {
            self.cluster.complete(pod)?;
        } else {
            let _ = self.cluster.delete_pod(pod);
        }
        let _ = self.ephemeral.destroy_volume(sid);
        Ok(())
    }

    /// Handle one event; periodic events re-arm themselves.
    pub fn handle(&mut self, t: Time, ev: Event) {
        match ev {
            Event::AdmissionCycle => {
                let admitted = self.kueue.admission_cycle(
                    &mut self.cluster,
                    &self.scheduler,
                    t,
                );
                for wl in admitted {
                    self.on_admitted(wl, t);
                }
                self.events.after(self.periods.admission, Event::AdmissionCycle);
            }
            Event::Reconcile => {
                let finished = self.vk.reconcile(&mut self.cluster, t);
                for (pod, state) in finished {
                    // O(log n) pod→workload lookup instead of scanning
                    // every workload per finished remote job.
                    let wl = self.kueue.workload_of_pod(pod).filter(|wid| {
                        self.kueue
                            .workload(*wid)
                            .map(|w| w.state == WorkloadState::Admitted)
                            .unwrap_or(false)
                    });
                    if let Some(wl) = wl {
                        let ok = state == crate::offload::RemoteState::Succeeded;
                        let _ = self.kueue.finish(&self.cluster, wl, ok, t);
                    }
                }
                self.events.after(self.periods.reconcile, Event::Reconcile);
            }
            Event::Scrape => {
                scrape_all(
                    &mut self.tsdb,
                    &self.cluster,
                    &self.nfs,
                    &self.kueue,
                    &self.vk,
                    t,
                );
                self.events.after(self.periods.scrape, Event::Scrape);
            }
            Event::AccountingUpdate => {
                self.accounting.update(&self.cluster, t);
                self.events
                    .after(self.periods.accounting, Event::AccountingUpdate);
            }
            Event::LocalJobDone(pod) => {
                if self.cluster.pod(pod).map(|p| p.phase)
                    == Some(PodPhase::Running)
                {
                    let _ = self.cluster.complete(pod);
                    if let Some(wl) = self.local_running.remove(&pod) {
                        let _ = self.kueue.finish(&self.cluster, wl, true, t);
                    }
                }
            }
            Event::SessionEnds(sid) => {
                let _ = self.end_session(&sid);
            }
            Event::CullPass => {
                for sid in self.hub.cull_candidates(t) {
                    self.trace.log(t, format!("culling idle session {sid}"));
                    let _ = self.end_session(&sid);
                }
                self.events.after(self.periods.cull, Event::CullPass);
            }
        }
    }

    /// Post-admission bookkeeping: local pods get a completion event,
    /// virtual pods go through interLink.
    fn on_admitted(&mut self, wl: WorkloadId, now: Time) {
        let w = self.kueue.workload(wl).unwrap();
        let pod = w.pod;
        let node = w.assigned_node.expect("admitted workload has a node");
        let is_virtual = self
            .cluster
            .node_by_id(node)
            .map(|n| n.virtual_node)
            .unwrap_or(false);
        if is_virtual {
            let backend = self
                .cluster
                .node_by_id(node)
                .unwrap()
                .backend
                .clone()
                .unwrap();
            let _ = self.vk.launch(&self.cluster, pod, &backend, now);
        } else {
            let runtime = self.cluster.pod(pod).unwrap().spec.est_runtime_s;
            self.local_running.insert(pod, wl);
            self.events.after(runtime, Event::LocalJobDone(pod));
        }
    }

    /// Drive the platform until `deadline` (virtual seconds).
    pub fn run_until(&mut self, deadline: Time) {
        // Pull the event queue out so handle() can schedule into it.
        let mut events = std::mem::take(&mut self.events);
        events.run_until(deadline, |q, t, ev| {
            // Temporarily give the queue back for re-arming.
            std::mem::swap(&mut self.events, q);
            self.handle(t, ev);
            std::mem::swap(&mut self.events, q);
        });
        self.events = events;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuModel;

    fn platform() -> Platform {
        let mut p = Platform::ai_infn(42);
        p.iam.register("rosa", "Rosa", &["lhcb-flashsim"]);
        p
    }

    #[test]
    fn spawn_and_end_session_roundtrip() {
        let mut p = platform();
        let sid = p.spawn_notebook("rosa", "gpu-nvidia-a100", 0.0).unwrap();
        assert_eq!(p.hub.active_count(), 1);
        assert_eq!(p.cluster.running_pods(), 1);
        assert!(p.ephemeral.volume(&sid).is_some());
        p.end_session(&sid).unwrap();
        assert_eq!(p.hub.active_count(), 0);
        assert_eq!(p.cluster.running_pods(), 0);
        assert!(p.ephemeral.volume(&sid).is_none());
        p.cluster.check_accounting().unwrap();
    }

    #[test]
    fn periodic_loops_rearm() {
        let mut p = platform();
        p.run_until(601.0);
        // scrape every 60 s → ≥10 scrapes ingested series
        assert!(p.tsdb.samples_ingested > 50);
        assert!(p.events.processed() > 20);
    }

    #[test]
    fn notebook_spawn_evicts_batch_under_contention() {
        let mut p = platform();
        // Saturate every A100 with batch jobs (5 A100s total).
        for i in 0..5 {
            let mut spec = crate::cluster::PodSpec::batch(
                "batch-user",
                crate::cluster::Resources {
                    gpus: 1,
                    gpu_model: Some(GpuModel::A100),
                    ..crate::cluster::Resources::cpu_mem(1000, GIB)
                },
                "train",
            );
            spec.est_runtime_s = 100_000.0;
            let pod = p.cluster.create_pod(spec);
            p.kueue
                .submit(pod, "local-batch", "batch-user", false, 0.0)
                .unwrap();
            let _ = i;
        }
        p.run_until(10.0); // admission cycle runs
        assert_eq!(p.cluster.running_pods(), 5);
        let sid = p.spawn_notebook("rosa", "gpu-nvidia-a100", 10.0).unwrap();
        assert_eq!(p.hub.active_count(), 1);
        assert!(p.kueue.n_evictions >= 1);
        // The evicted workload is requeued, not lost.
        assert!(p.kueue.pending_count() >= 1);
        let _ = sid;
        p.cluster.check_accounting().unwrap();
    }

    #[test]
    fn local_batch_completes_via_event() {
        let mut p = platform();
        let spec = crate::cluster::PodSpec::batch(
            "rosa",
            crate::cluster::Resources::flashsim_cpu(),
            "flashsim",
        )
        .with_runtime(120.0);
        let pod = p.cluster.create_pod(spec);
        let wl = p.kueue.submit(pod, "local-batch", "rosa", false, 0.0).unwrap();
        p.run_until(300.0);
        assert_eq!(p.cluster.pod(pod).unwrap().phase, PodPhase::Succeeded);
        assert_eq!(
            p.kueue.workload(wl).unwrap().state,
            WorkloadState::Finished
        );
    }

    #[test]
    fn determinism_same_seed_same_state() {
        let run = |seed| {
            let mut p = Platform::ai_infn(seed);
            p.iam.register("rosa", "Rosa", &["lhcb-flashsim"]);
            for i in 0..50 {
                let spec = crate::cluster::PodSpec::batch(
                    "rosa",
                    crate::cluster::Resources::flashsim_cpu(),
                    "fs",
                )
                .with_runtime(300.0 + i as f64);
                let mut spec = spec;
                spec.offload_compatible = true;
                spec.tolerations.push("interlink.virtual-node".into());
                let pod = p.cluster.create_pod(spec);
                p.kueue.submit(pod, "local-batch", "rosa", true, 0.0).unwrap();
            }
            p.run_until(3600.0);
            (
                p.events.processed(),
                p.kueue.n_admitted_local,
                p.kueue.n_admitted_virtual,
                p.tsdb.samples_ingested,
            )
        };
        assert_eq!(run(7), run(7));
    }
}
