//! PJRT runtime: load and execute the AOT artifacts (L2/L1 → HLO text).
//!
//! This is the only place the ML payload touches Rust: `make artifacts`
//! lowers the JAX flash-sim model (with its Pallas kernel) to HLO text
//! once; this module compiles it on the PJRT CPU client and executes it
//! on the job hot path. Python never runs at request time.
//!
//! Gotcha inherited from the image (see /opt/xla-example/README.md): the
//! interchange format is HLO *text*, not serialized HloModuleProto —
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids.

use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::{anyhow, ensure};

use crate::util::json::Json;

/// In-repo stub of the xla-rs PJRT bindings (offline build — see the
/// module docs in [`xla`] for how to wire in the real crate).
pub mod xla;

/// Artifact metadata written by `python/compile/aot.py`.
#[derive(Clone, Debug)]
pub struct Meta {
    pub n_cond: usize,
    pub n_latent: usize,
    pub n_obs: usize,
    pub gen_params: usize,
    pub disc_params: usize,
    pub batch_gen: usize,
    pub batch_train: usize,
}

impl Meta {
    pub fn load(dir: &Path) -> Result<Meta> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .context("reading artifacts/meta.json (run `make artifacts`)")?;
        let j = Json::parse(&text).map_err(|e| anyhow!("meta.json: {e}"))?;
        let get = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("meta.json missing {k}"))
        };
        Ok(Meta {
            n_cond: get("n_cond")?,
            n_latent: get("n_latent")?,
            n_obs: get("n_obs")?,
            gen_params: get("gen_params")?,
            disc_params: get("disc_params")?,
            batch_gen: get("batch_gen")?,
            batch_train: get("batch_train")?,
        })
    }
}

/// A compiled artifact on the PJRT CPU client.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Executable({})", self.name)
    }
}

/// The runtime: one PJRT client + the flash-sim executables.
pub struct Runtime {
    client: xla::PjRtClient,
    pub meta: Meta,
    dir: PathBuf,
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("platform", &self.client.platform_name())
            .field("meta", &self.meta)
            .finish()
    }
}

impl Runtime {
    /// Create a CPU PJRT client and read artifact metadata.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let meta = Meta::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client, meta, dir })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact.
    pub fn load(&self, file: &str) -> Result<Executable> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {file}: {e:?}"))?;
        Ok(Executable { exe, name: file.to_string() })
    }

    /// Load a little-endian f32 parameter file.
    pub fn load_params(&self, file: &str, expect_len: usize) -> Result<Vec<f32>> {
        let bytes = std::fs::read(self.dir.join(file))
            .with_context(|| format!("reading {file}"))?;
        if bytes.len() != expect_len * 4 {
            return Err(anyhow!(
                "{file}: {} bytes, expected {}",
                bytes.len(),
                expect_len * 4
            ));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Execute with f32 tensor inputs (shape per tensor). The artifact
    /// was lowered with `return_tuple=True`; outputs come back as a
    /// flat list of f32 vectors.
    pub fn execute_f32(
        &self,
        exe: &Executable,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let lit = xla::Literal::vec1(data)
                .reshape(shape)
                .map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))?;
            literals.push(lit);
        }
        let result = exe
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", exe.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let elements = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        let mut out = Vec::with_capacity(elements.len());
        for el in elements {
            out.push(el.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?);
        }
        Ok(out)
    }
}

/// High-level flash-sim payload executor (what a worker runs per job).
pub struct FlashSim {
    pub runtime: Runtime,
    gen_exe: Executable,
    pub gen_params: Vec<f32>,
    /// §Perf iteration 2: the parameter literal is built once — the
    /// naive path re-copied 42 k floats into a fresh literal per batch.
    gen_params_lit: xla::Literal,
}

impl std::fmt::Debug for FlashSim {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "FlashSim(batch={})", self.runtime.meta.batch_gen)
    }
}

impl FlashSim {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<FlashSim> {
        let runtime = Runtime::new(artifacts_dir)?;
        let gen_exe = runtime.load("flashsim_gen.hlo.txt")?;
        let gen_params = runtime
            .load_params("flashsim_gen_params.bin", runtime.meta.gen_params)?;
        let gen_params_lit = xla::Literal::vec1(&gen_params);
        Ok(FlashSim { runtime, gen_exe, gen_params, gen_params_lit })
    }

    /// Generate one batch of observables from latent noise + conditions.
    /// `z` is (batch_gen × n_latent), `cond` is (batch_gen × n_cond).
    pub fn generate(&self, z: &[f32], cond: &[f32]) -> Result<Vec<f32>> {
        let m = &self.runtime.meta;
        ensure!(z.len() == m.batch_gen * m.n_latent, "z shape");
        ensure!(cond.len() == m.batch_gen * m.n_cond, "cond shape");
        let z_lit = xla::Literal::vec1(z)
            .reshape(&[m.batch_gen as i64, m.n_latent as i64])
            .map_err(|e| anyhow!("reshape z: {e:?}"))?;
        let cond_lit = xla::Literal::vec1(cond)
            .reshape(&[m.batch_gen as i64, m.n_cond as i64])
            .map_err(|e| anyhow!("reshape cond: {e:?}"))?;
        let result = self
            .gen_exe
            .exe
            .execute::<&xla::Literal>(&[&self.gen_params_lit, &z_lit, &cond_lit])
            .map_err(|e| anyhow!("execute generate: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let out = tuple.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Generate `events` observables, batching through the fixed-shape
    /// executable; returns (events, wall seconds, events/sec).
    ///
    /// §Perf iteration 1: the naive per-element `rng.normal()` fill
    /// (scalar Box–Muller with a cos per sample) cost ~2/3 of the loop;
    /// this version generates sin/cos *pairs* (both Box–Muller outputs)
    /// straight into the f32 buffer and fills the uniform conditions
    /// from raw bits — leaving the PJRT execute as the dominant cost.
    pub fn run_job(
        &self,
        events: u64,
        rng: &mut crate::util::rng::Rng,
    ) -> Result<(u64, f64, f64)> {
        let m = &self.runtime.meta;
        let batches = events.div_ceil(m.batch_gen as u64);
        let mut z = vec![0f32; m.batch_gen * m.n_latent];
        let mut cond = vec![0f32; m.batch_gen * m.n_cond];
        let start = std::time::Instant::now();
        let mut checksum = 0f64;
        for _ in 0..batches {
            fill_normal_f32(&mut z, rng);
            fill_uniform_f32(&mut cond, -1.0, 1.0, rng);
            let obs = self.generate(&z, &cond)?;
            checksum += obs[0] as f64; // keep the optimizer honest
        }
        let secs = start.elapsed().as_secs_f64();
        ensure!(checksum.is_finite(), "non-finite output");
        let done = batches * m.batch_gen as u64;
        Ok((done, secs, done as f64 / secs))
    }
}

/// Fill a buffer with standard normals using both Box–Muller outputs
/// per transcendental pair (≈2.4× the scalar `rng.normal()` fill).
pub fn fill_normal_f32(buf: &mut [f32], rng: &mut crate::util::rng::Rng) {
    let mut i = 0;
    while i + 1 < buf.len() {
        let u1 = rng.f64().max(f64::MIN_POSITIVE);
        let u2 = rng.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        buf[i] = (r * c) as f32;
        buf[i + 1] = (r * s) as f32;
        i += 2;
    }
    if i < buf.len() {
        buf[i] = rng.normal() as f32;
    }
}

/// Fill a buffer with uniforms in [lo, hi) straight from raw bits.
pub fn fill_uniform_f32(
    buf: &mut [f32],
    lo: f32,
    hi: f32,
    rng: &mut crate::util::rng::Rng,
) {
    let span = hi - lo;
    for v in buf.iter_mut() {
        // 24 mantissa bits are plenty for f32 uniforms.
        let bits = (rng.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32);
        *v = lo + span * bits;
    }
}

#[cfg(test)]
mod tests {
    //! Runtime tests compile the small `smoke.hlo.txt` artifact (the
    //! flash-sim executables are exercised by the integration tests and
    //! examples — compiling them here would slow `cargo test`).

    use super::*;

    fn artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts().join("meta.json").exists()
    }

    #[test]
    fn meta_parses_and_matches_model_dims() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let meta = Meta::load(&artifacts()).unwrap();
        assert_eq!(meta.n_cond, 6);
        assert_eq!(meta.n_latent, 64);
        assert_eq!(meta.n_obs, 4);
        assert!(meta.gen_params > 10_000);
    }

    #[test]
    fn smoke_artifact_executes_correctly() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(artifacts()).unwrap();
        let exe = rt.load("smoke.hlo.txt").unwrap();
        // fn(x, y) = matmul(x, y) + 2 over f32[2,2]
        let x = [1f32, 2.0, 3.0, 4.0];
        let y = [1f32, 1.0, 1.0, 1.0];
        let out = rt
            .execute_f32(&exe, &[(&x, &[2, 2]), (&y, &[2, 2])])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], vec![5.0, 5.0, 9.0, 9.0]);
    }

    #[test]
    fn params_length_validated() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(artifacts()).unwrap();
        assert!(rt.load_params("flashsim_gen_params.bin", 7).is_err());
        let params = rt
            .load_params("flashsim_gen_params.bin", rt.meta.gen_params)
            .unwrap();
        assert!(params.iter().all(|p| p.is_finite()));
    }
}

#[cfg(test)]
mod fill_tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fill_normal_moments_and_odd_len() {
        let mut rng = Rng::new(1);
        let mut buf = vec![0f32; 100_001];
        fill_normal_f32(&mut buf, &mut rng);
        let n = buf.len() as f64;
        let mean = buf.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var = buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn fill_uniform_bounds_and_spread() {
        let mut rng = Rng::new(2);
        let mut buf = vec![0f32; 100_000];
        fill_uniform_f32(&mut buf, -1.0, 1.0, &mut rng);
        assert!(buf.iter().all(|&x| (-1.0..1.0).contains(&x)));
        let mean = buf.iter().map(|&x| x as f64).sum::<f64>() / buf.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
    }
}
