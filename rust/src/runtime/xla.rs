//! In-repo stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The offline build environment has no crates.io access and xla-rs is
//! a git dependency upstream, so a fresh clone compiles against this
//! stub: the type and call surface matches exactly what
//! [`super`] (the runtime module) uses, and every fallible operation
//! returns [`STUB_ERR`] at runtime — `FlashSim::load` fails cleanly at
//! client creation, which every caller (CLI, benches, examples)
//! already handles by skipping the PJRT payload.
//!
//! To execute real artifacts, delete this file and the `pub mod xla;`
//! line in `runtime/mod.rs`, then add the real bindings to Cargo.toml
//! (`xla = { git = "https://github.com/LaurentMazare/xla-rs" }` or a
//! vendored checkout) — no other code changes are needed.

#![allow(dead_code)]

use std::borrow::Borrow;
use std::path::Path;

pub const STUB_ERR: &str = "PJRT unavailable: built against the in-repo \
    xla stub (rust/src/runtime/xla.rs); wire in the real xla-rs bindings \
    to execute artifacts";

#[derive(Debug)]
pub struct XlaError(pub String);

fn stub_err<T>() -> Result<T, XlaError> {
    Err(XlaError(STUB_ERR.to_string()))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        stub_err()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        stub_err()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(
        _path: impl AsRef<Path>,
    ) -> Result<HloModuleProto, XlaError> {
        stub_err()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        stub_err()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        stub_err()
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        stub_err()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        stub_err()
    }

    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        stub_err()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        stub_err()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_fails_with_the_stub_error() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("xla stub"));
    }
}
