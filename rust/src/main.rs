//! `ainfn` — the AI_INFN platform reproduction CLI.
//!
//! ```text
//! ainfn inventory                    # §2 server table (TAB1)
//! ainfn fig2 [--jobs N] [--seed S]   # Figure 2 scalability test
//! ainfn storage                      # §3 I/O spectrum (STO1)
//! ainfn envs                         # conda vs apptainer (ENV1)
//! ainfn eviction [--notebooks N]     # Kueue contention (KUE1)
//! ainfn crossover                    # offload effectiveness (OFF1)
//! ainfn vm-vs-platform [--days N]    # §2 motivation replay (MOT1)
//! ainfn fed-stress [--workers N]     # federation stress (indexed sched)
//! ainfn fed-stress --cohort          # quota-tree borrow/reclaim phase
//! ainfn fed-stress --slices          # GPU partition slice-wave phase
//! ainfn fed-stress --serving         # inference autoscale phase (SRV1)
//! ainfn fed-stress --chaos           # fault-injection phase (CHA1)
//! ainfn fed-stress --xl              # 100k-node sharded-core phase (XL1)
//! ainfn fed-stress --fl              # federated-learning rounds (FL1)
//! ainfn flashsim [--events N]        # run the REAL PJRT payload
//! ainfn demo                         # guided end-to-end tour
//! ```
//!
//! Every experiment prints its table, writes CSV under `results/`, and
//! reports the seed so runs are reproducible.

use ai_infn::experiments::{self, fig2};
use ai_infn::util::cli::Command;

fn save(table: &ai_infn::util::csv::Table, name: &str) {
    let path = format!("results/{name}.csv");
    match table.write_file(&path) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

fn cmd_inventory() {
    println!("§2 hardware inventory (TAB1)\n");
    let t = experiments::tab1::inventory_table();
    println!("{}", t.to_aligned());
    let f = experiments::tab1::flavor_table();
    println!("{}", f.to_aligned());
    save(&t, "tab1_inventory");
    save(&f, "tab1_flavors");
}

fn cmd_fig2(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("fig2", "Figure 2 scalability test")
        .opt("jobs", "1500", "campaign size")
        .opt("seed", "20260710", "PRNG seed")
        .opt("horizon", "10800", "simulated seconds")
        .flag("quiet", "skip the ASCII plot");
    let p = cmd.parse(args)?;
    let cfg = fig2::Fig2Config {
        seed: p.u64("seed")?,
        n_jobs: p.usize("jobs")?,
        horizon_s: p.f64("horizon")?,
        ..Default::default()
    };
    println!(
        "FIG2: {} flash-sim jobs over the federated testbed (seed {})",
        cfg.n_jobs, cfg.seed
    );
    let result = fig2::run_fig2(&cfg);
    if !p.flag("quiet") {
        println!("{}", fig2::plot(&result));
    }
    println!(
        "completed {} jobs; peak concurrent running {}",
        result.total_completed, result.peak_total_running
    );
    save(&result.table, "fig2_scalability");
    Ok(())
}

fn cmd_storage() {
    println!("§3 storage I/O spectrum (STO1)\n");
    let (_, t) = experiments::storage_tiers::run_storage_tiers(
        &experiments::storage_tiers::StorageConfig::default(),
    );
    println!("{}", t.to_aligned());
    save(&t, "sto1_storage_tiers");
}

fn cmd_envs() {
    println!("§3 environment distribution (ENV1)\n");
    let (_, t) = experiments::env_distribution::run_env_distribution(1);
    println!("{}", t.to_aligned());
    save(&t, "env1_distribution");
}

fn cmd_eviction(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("eviction", "Kueue contention test")
        .opt("notebooks", "15", "notebook wave size")
        .opt("seed", "5", "PRNG seed");
    let p = cmd.parse(args)?;
    let (_, t) = experiments::kueue_eviction::run_kueue_eviction(
        p.u64("seed")?,
        p.usize("notebooks")?,
    );
    println!("§4 opportunistic batch vs notebooks (KUE1)\n");
    println!("{}", t.to_aligned());
    save(&t, "kue1_eviction");
    Ok(())
}

fn cmd_crossover(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("crossover", "offload effectiveness sweep")
        .opt("jobs", "600", "campaign size")
        .opt("seed", "11", "PRNG seed");
    let p = cmd.parse(args)?;
    println!("§4 offload crossover (OFF1) — this sweeps several runtimes…\n");
    let (_, t, crossover) = experiments::offload_crossover::run_offload_crossover(
        p.u64("seed")?,
        p.usize("jobs")?,
        &[120.0, 600.0, 1800.0, 3600.0, 7200.0],
    );
    println!("{}", t.to_aligned());
    match crossover {
        Some(c) => println!("offloading starts to pay at ≈{c:.0}s jobs"),
        None => println!("no crossover in the swept range"),
    }
    save(&t, "off1_crossover");
    Ok(())
}

fn cmd_vm_vs_platform(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("vm-vs-platform", "§2 motivation replay")
        .opt("days", "60", "working days to replay")
        .opt("seed", "42", "PRNG seed");
    let p = cmd.parse(args)?;
    let (_, _, t) = experiments::vm_vs_platform::run_vm_vs_platform(
        p.usize("days")?,
        p.u64("seed")?,
    );
    println!("ML_INFN VM model vs AI_INFN platform (MOT1)\n");
    println!("{}", t.to_aligned());
    save(&t, "mot1_vm_vs_platform");
    Ok(())
}

fn cmd_fed_stress(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("fed-stress", "federation stress scenario")
        .opt("workers", "5000", "local worker nodes")
        .opt("burst", "45000", "offloadable burst jobs")
        .opt("notebooks", "50", "contention notebooks")
        .opt("horizon", "600", "simulated seconds")
        .opt("seed", "20260731", "PRNG seed")
        .opt("loop-mode", "reactive", "coordinator loop: reactive|polling")
        .opt(
            "job-cpu",
            "16000",
            "cohort phase only: per-job CPU millicores",
        )
        .flag("linear", "use the linear-scan baseline scheduler")
        .flag(
            "cohort",
            "run the cohort-contention quota phase (borrower burst + \
             owner reclaim wave) instead of the federation burst; uses \
             --workers/--horizon/--seed/--job-cpu (--burst/--notebooks \
             do not apply)",
        )
        .flag(
            "slices",
            "run the GPU slice-wave phase (whole-device holders vs a \
             carved-partition notebook wave) instead of the federation \
             burst; uses --workers/--seed/--loop-mode/--linear; with \
             --check-modes also verifies ≥2× co-residency vs the \
             whole-GPU baseline",
        )
        .flag(
            "serving",
            "run the inference-serving autoscale phase (diurnal + \
             flash-crowd trace, SLO-driven replica scaling on MIG \
             slices, mid-flash notebook reclaim) instead of the \
             federation burst; uses --seed/--loop-mode/--linear; with \
             --check-modes also verifies the p99 SLO and that the \
             autoscaler beats the static-replica baseline on occupancy",
        )
        .flag(
            "chaos",
            "run the fault-injection phase (rolling node crashes with a \
             second tap per victim + a mid-run WAN blackout toward one \
             interLink site, under the deterministic FaultPlan) instead \
             of the plain federation burst; uses --workers/--burst/\
             --notebooks/--horizon/--seed/--loop-mode/--linear; with \
             --check-modes also gates on zero lost workloads, bounded \
             recovery time and clean accounting at every sample",
        )
        .flag(
            "xl",
            "run the xl sharded-core phase (site-skewed 100k-node farm, \
             1M-pod parallel placement storm through the per-site \
             shards, short Kueue tail) instead of the federation burst; \
             uses --seed/--loop-mode/--linear plus --xl-nodes/--xl-pods/\
             --shards/--threads/--commit-threads; AINFN_XL_NODES/\
             AINFN_XL_PODS/AINFN_XL_SHARDS env vars override the size \
             opts (the CI gate runs reduced); with --check-modes \
             compares the placement digest across all 4 mode \
             combinations, every worker/commit-width combination, and \
             gates the reactive loop's shard-visit pruning",
        )
        .flag(
            "fl",
            "run the federated-learning round phase (coordinator-driven \
             Select→Distribute→Update→Sum→Commit rounds over a \
             million-client population split across the interLink \
             sites, with straggler tails, dropouts and a notebook \
             reclaim wave) instead of the federation burst; uses \
             --seed/--loop-mode/--linear plus --fl-rounds/--fl-clients/\
             --fl-population; with --check-modes also gates the \
             chaos-outage variant (zero wedged rounds) and the \
             population-independence of the event count",
        )
        .opt("fl-rounds", "5", "fl phase: rounds to run")
        .opt("fl-clients", "100000", "fl phase: clients selected per round")
        .opt(
            "fl-population",
            "1200000",
            "fl phase: total simulated client population",
        )
        .opt("xl-nodes", "100000", "xl phase: farm nodes")
        .opt("xl-pods", "1000000", "xl phase: placement-storm pods")
        .opt("shards", "64", "xl phase: scheduling shards")
        .opt("threads", "8", "xl phase: scatter worker threads")
        .opt(
            "commit-threads",
            "0",
            "xl phase: commit-stage worker threads (0 = follow --threads)",
        )
        .flag(
            "static-replicas",
            "serving phase only: pin the fleet at max_replicas (the \
             static baseline) instead of autoscaling",
        )
        .flag(
            "whole-gpu",
            "slice phase only: request the wave as whole devices (the \
             stranding baseline) instead of carved partitions",
        )
        .flag(
            "check-modes",
            "run every placement×loop combination and fail on any \
             cross-mode placement-CSV divergence (CI gate)",
        );
    let p = cmd.parse(args)?;
    let loop_mode = match p.str("loop-mode") {
        "reactive" => ai_infn::coordinator::LoopMode::Reactive,
        "polling" => ai_infn::coordinator::LoopMode::Polling,
        other => return Err(format!("unknown --loop-mode {other}")),
    };
    if p.flag("serving") {
        let cfg = experiments::serving::ServingConfig {
            seed: p.u64("seed")?,
            static_mode: p.flag("static-replicas"),
            placement: if p.flag("linear") {
                ai_infn::cluster::PlacementMode::LinearScan
            } else {
                ai_infn::cluster::PlacementMode::Indexed
            },
            loop_mode,
            ..Default::default()
        };
        if p.flag("check-modes") {
            return check_modes_serving(&cfg);
        }
        return run_serving(&cfg);
    }
    if p.flag("fl") {
        let cfg = experiments::fl_rounds::FlRoundsConfig {
            seed: p.u64("seed")?,
            n_rounds: p.u64("fl-rounds")? as u32,
            clients_per_round: p.u64("fl-clients")?,
            population: p.u64("fl-population")?,
            placement: if p.flag("linear") {
                ai_infn::cluster::PlacementMode::LinearScan
            } else {
                ai_infn::cluster::PlacementMode::Indexed
            },
            loop_mode,
            ..Default::default()
        };
        if p.flag("check-modes") {
            return check_modes_fl(&cfg);
        }
        return run_fl(&cfg);
    }
    if p.flag("chaos") {
        let cfg = experiments::chaos_stress::ChaosStressConfig {
            seed: p.u64("seed")?,
            n_workers: p.usize("workers")?,
            n_burst: p.usize("burst")?,
            n_notebooks: p.usize("notebooks")?,
            horizon_s: p.f64("horizon")?,
            placement: if p.flag("linear") {
                ai_infn::cluster::PlacementMode::LinearScan
            } else {
                ai_infn::cluster::PlacementMode::Indexed
            },
            loop_mode,
            ..Default::default()
        };
        if p.flag("check-modes") {
            return check_modes_chaos(&cfg);
        }
        return run_chaos(&cfg);
    }
    if p.flag("slices") {
        let mut cfg = experiments::fed_stress::SliceWaveConfig::scaled(
            p.usize("workers")?,
        );
        cfg.seed = p.u64("seed")?;
        cfg.use_slices = !p.flag("whole-gpu");
        cfg.placement = if p.flag("linear") {
            ai_infn::cluster::PlacementMode::LinearScan
        } else {
            ai_infn::cluster::PlacementMode::Indexed
        };
        cfg.loop_mode = loop_mode;
        if p.flag("check-modes") {
            return check_modes_slices(&cfg);
        }
        return run_slices(&cfg);
    }
    if p.flag("xl") {
        let env = |k: &str| {
            std::env::var(k).ok().and_then(|v| v.parse::<usize>().ok())
        };
        let cfg = experiments::fed_stress::XlStressConfig {
            seed: p.u64("seed")?,
            n_nodes: env("AINFN_XL_NODES").unwrap_or(p.usize("xl-nodes")?),
            n_pods: env("AINFN_XL_PODS").unwrap_or(p.usize("xl-pods")?),
            n_shards: env("AINFN_XL_SHARDS").unwrap_or(p.usize("shards")?),
            workers: p.usize("threads")?,
            commit_workers: p.usize("commit-threads")?,
            placement: if p.flag("linear") {
                ai_infn::cluster::PlacementMode::LinearScan
            } else {
                ai_infn::cluster::PlacementMode::Indexed
            },
            loop_mode,
            ..Default::default()
        };
        if p.flag("check-modes") {
            return check_modes_xl(&cfg);
        }
        return run_xl(&cfg);
    }
    if p.flag("cohort") {
        let horizon_s = p.f64("horizon")?;
        // Owner wave at mid-horizon, floored onto the 30 s sample grid.
        let reclaim_at_s = ((horizon_s / 2.0) / 30.0).floor().max(1.0) * 30.0;
        let cfg = experiments::fed_stress::CohortStressConfig {
            seed: p.u64("seed")?,
            n_workers: p.usize("workers")?,
            job_cpu_m: p.u64("job-cpu")?,
            horizon_s,
            reclaim_at_s,
            placement: if p.flag("linear") {
                ai_infn::cluster::PlacementMode::LinearScan
            } else {
                ai_infn::cluster::PlacementMode::Indexed
            },
            loop_mode,
            ..Default::default()
        };
        if p.flag("check-modes") {
            return check_modes_cohort(&cfg);
        }
        return run_cohort(&cfg);
    }
    let cfg = experiments::fed_stress::FedStressConfig {
        seed: p.u64("seed")?,
        n_workers: p.usize("workers")?,
        n_burst: p.usize("burst")?,
        n_notebooks: p.usize("notebooks")?,
        horizon_s: p.f64("horizon")?,
        placement: if p.flag("linear") {
            ai_infn::cluster::PlacementMode::LinearScan
        } else {
            ai_infn::cluster::PlacementMode::Indexed
        },
        loop_mode,
        ..Default::default()
    };
    if p.flag("check-modes") {
        return check_modes(&cfg);
    }
    println!(
        "FED-STRESS: {} workers / {} burst jobs / ≤{} notebooks \
         (seed {}, {:?}, {:?})",
        cfg.n_workers,
        cfg.n_burst,
        cfg.n_notebooks,
        cfg.seed,
        cfg.placement,
        cfg.loop_mode
    );
    let started = std::time::Instant::now();
    let r = experiments::fed_stress::run_fed_stress(&cfg);
    println!("{}", r.table.to_aligned());
    println!(
        "{} pods total ({} fillers, {} notebooks spawned); \
         admitted {} local / {} virtual; \
         {} evictions; {} still pending; {} events \
         ({} controller cycles: {:?}) in {:.2}s wall",
        r.n_pods,
        r.n_fillers,
        r.notebooks_spawned,
        r.admitted_local,
        r.admitted_virtual,
        r.evictions,
        r.pending_end,
        r.events_processed,
        r.cycles.total(),
        r.cycles,
        started.elapsed().as_secs_f64()
    );
    save(&r.table, "fed_stress");
    save(&r.placements, "fed_stress_placements");
    Ok(())
}

/// Run and report the inference-serving autoscale phase.
fn run_serving(
    cfg: &experiments::serving::ServingConfig,
) -> Result<(), String> {
    println!(
        "FED-STRESS --serving: {} base rps over {}s, flash {} rps for \
         {}s at t={}s, {} fleet (seed {}, {:?}, {:?})",
        cfg.base_rps,
        cfg.horizon_s,
        cfg.flash_rps,
        cfg.flash_len_s,
        cfg.flash_at_s,
        if cfg.static_mode { "static" } else { "autoscaled" },
        cfg.seed,
        cfg.placement,
        cfg.loop_mode
    );
    let started = std::time::Instant::now();
    let r = experiments::serving::run_serving(cfg);
    println!("{}", r.table.to_aligned());
    println!(
        "{} requests arrived / {} served / {} queued; p50 {}µs, p99 \
         {}µs vs {}µs SLO ({} violations); occupancy {}‰; {} replicas \
         spawned / {} retired / {} live ({} ups, {} downs); {} reclaim \
         evictions; {} events ({} controller cycles) in {:.2}s wall",
        r.arrived,
        r.served,
        r.queue_end,
        r.p50_us,
        r.p99_us,
        r.slo_target_us,
        r.slo_violations,
        r.occupancy_permille,
        r.spawned,
        r.retired,
        r.live,
        r.scale_ups,
        r.scale_downs,
        r.reclaim_evictions,
        r.events_processed,
        r.cycles.total(),
        started.elapsed().as_secs_f64()
    );
    if let Some(v) = &r.accounting_violation {
        return Err(format!("cluster accounting violated: {v}"));
    }
    save(&r.table, "serving");
    save(&r.placements, "serving_placements");
    Ok(())
}

/// The serving flavour of the CI cross-mode gate: byte-identical CSVs
/// across the 2×2 matrix, the p99 SLO held through the flash crowd,
/// and the autoscaler strictly beating the static-replica baseline on
/// GPU occupancy.
fn check_modes_serving(
    base: &experiments::serving::ServingConfig,
) -> Result<(), String> {
    use ai_infn::cluster::PlacementMode;
    use ai_infn::coordinator::LoopMode;
    let mut reference: Option<(String, String)> = None;
    let mut auto_occupancy = 0u64;
    for placement in [PlacementMode::Indexed, PlacementMode::LinearScan] {
        for loop_mode in [LoopMode::Polling, LoopMode::Reactive] {
            let cfg = experiments::serving::ServingConfig {
                placement,
                loop_mode,
                static_mode: false,
                ..base.clone()
            };
            let started = std::time::Instant::now();
            let r = experiments::serving::run_serving(&cfg);
            println!(
                "  {placement:?}/{loop_mode:?}: p99 {}µs, {} violations, \
                 occupancy {}‰, {} reclaim evictions, {} events, \
                 {:.2}s wall",
                r.p99_us,
                r.slo_violations,
                r.occupancy_permille,
                r.reclaim_evictions,
                r.events_processed,
                started.elapsed().as_secs_f64()
            );
            if let Some(v) = &r.accounting_violation {
                return Err(format!(
                    "cluster accounting violated under \
                     {placement:?}/{loop_mode:?}: {v}"
                ));
            }
            if r.arrived != r.served + r.queue_end {
                return Err(format!(
                    "request conservation broken under \
                     {placement:?}/{loop_mode:?}: {} arrived vs {} \
                     served + {} queued",
                    r.arrived, r.served, r.queue_end
                ));
            }
            if r.p99_us > r.slo_target_us {
                return Err(format!(
                    "serving acceptance failed under {placement:?}/\
                     {loop_mode:?}: p99 {}µs blew the {}µs SLO ({} \
                     violations of {} served)",
                    r.p99_us, r.slo_target_us, r.slo_violations, r.served
                ));
            }
            if r.reclaim_evictions == 0 {
                return Err(format!(
                    "serving acceptance failed under {placement:?}/\
                     {loop_mode:?}: the notebook wave reclaimed nothing"
                ));
            }
            auto_occupancy = r.occupancy_permille;
            let csvs = (r.placements.to_csv(), r.table.to_csv());
            match &reference {
                None => reference = Some(csvs),
                Some(reference) => {
                    if *reference != csvs {
                        return Err(format!(
                            "cross-mode divergence under \
                             {placement:?}/{loop_mode:?}: placement or \
                             serving-series CSV differs from the first \
                             mode"
                        ));
                    }
                }
            }
        }
    }
    // The static-replica baseline (indexed/default loop) for the
    // occupancy acceptance.
    let fixed = experiments::serving::run_serving(
        &experiments::serving::ServingConfig {
            static_mode: true,
            placement: PlacementMode::Indexed,
            ..base.clone()
        },
    );
    println!(
        "  static baseline: p99 {}µs, occupancy {}‰",
        fixed.p99_us, fixed.occupancy_permille
    );
    if auto_occupancy <= fixed.occupancy_permille {
        return Err(format!(
            "serving acceptance failed: autoscaled occupancy {}‰ does \
             not beat the static baseline's {}‰",
            auto_occupancy, fixed.occupancy_permille
        ));
    }
    println!(
        "check-modes OK: all 4 serving mode combinations byte-identical; \
         p99 within SLO; occupancy {}‰ vs static {}‰",
        auto_occupancy, fixed.occupancy_permille
    );
    Ok(())
}

/// Run and report the federated-learning round phase.
fn run_fl(
    cfg: &experiments::fl_rounds::FlRoundsConfig,
) -> Result<(), String> {
    println!(
        "FED-STRESS --fl: {} rounds x {} clients over a {}-client \
         population, quorum {}‰, horizon {}s (seed {}, {:?}, {:?})",
        cfg.n_rounds,
        cfg.clients_per_round,
        cfg.population,
        cfg.quorum_permille,
        cfg.horizon_s,
        cfg.seed,
        cfg.placement,
        cfg.loop_mode
    );
    let started = std::time::Instant::now();
    let r = experiments::fl_rounds::run_fl_rounds(cfg);
    println!("{}", r.table.to_aligned());
    println!(
        "{} rounds committed ({} quorum timeouts, {} wedged); {} \
         clients selected / {} updates / {} dropouts / {} late; {} pods \
         spawned / {} retired; {} reclaim evictions; {} events ({} \
         controller cycles) in {:.2}s wall",
        r.rounds_committed,
        r.quorum_timeouts,
        r.wedged_rounds,
        r.clients_selected,
        r.updates_received,
        r.dropouts,
        r.late,
        r.spawned,
        r.retired,
        r.reclaim_evictions,
        r.events_processed,
        r.cycles.total(),
        started.elapsed().as_secs_f64()
    );
    if r.rounds_committed != cfg.n_rounds as u64 {
        return Err(format!(
            "{} of {} rounds committed: a round wedged",
            r.rounds_committed, cfg.n_rounds
        ));
    }
    if let Some(v) = &r.conservation_violation {
        return Err(format!("client conservation broken: {v}"));
    }
    if let Some(v) = &r.accounting_violation {
        return Err(format!("cluster accounting violated: {v}"));
    }
    save(&r.table, "fl");
    save(&r.placements, "fl_placements");
    Ok(())
}

/// The FL flavour of the CI cross-mode gate: byte-identical
/// round/placement CSVs across the 2×2 matrix (plain and under a
/// site-outage plan), every round committed — never wedged — with exact
/// client conservation, and a coordinator event count independent of
/// the population size (the zero-per-client-event claim).
fn check_modes_fl(
    base: &experiments::fl_rounds::FlRoundsConfig,
) -> Result<(), String> {
    use ai_infn::cluster::PlacementMode;
    use ai_infn::coordinator::LoopMode;
    for chaos in [false, true] {
        let mut reference: Option<(String, String)> = None;
        for placement in [PlacementMode::Indexed, PlacementMode::LinearScan]
        {
            for loop_mode in [LoopMode::Polling, LoopMode::Reactive] {
                let cfg = experiments::fl_rounds::FlRoundsConfig {
                    placement,
                    loop_mode,
                    chaos,
                    // The blackout freezes the biggest cohort (35% of
                    // the population), so the outage variant runs at a
                    // quorum the remaining sites can still reach.
                    quorum_permille: if chaos {
                        600
                    } else {
                        base.quorum_permille
                    },
                    ..base.clone()
                };
                let started = std::time::Instant::now();
                let r = experiments::fl_rounds::run_fl_rounds(&cfg);
                println!(
                    "  {placement:?}/{loop_mode:?}{}: {} rounds, {} \
                     quorum timeouts, {} late, {} reclaim evictions, {} \
                     events, {:.2}s wall",
                    if chaos { " +outage" } else { "" },
                    r.rounds_committed,
                    r.quorum_timeouts,
                    r.late,
                    r.reclaim_evictions,
                    r.events_processed,
                    started.elapsed().as_secs_f64()
                );
                if r.wedged_rounds != 0 {
                    return Err(format!(
                        "fl acceptance failed under {placement:?}/\
                         {loop_mode:?} (chaos={chaos}): {} of {} rounds \
                         wedged",
                        r.wedged_rounds, cfg.n_rounds
                    ));
                }
                if let Some(v) = &r.conservation_violation {
                    return Err(format!(
                        "client conservation broken under {placement:?}/\
                         {loop_mode:?} (chaos={chaos}): {v}"
                    ));
                }
                if let Some(v) = &r.accounting_violation {
                    return Err(format!(
                        "cluster accounting violated under \
                         {placement:?}/{loop_mode:?} (chaos={chaos}): {v}"
                    ));
                }
                if r.heap_entries_max > 256 {
                    return Err(format!(
                        "timer churn unbounded under {placement:?}/\
                         {loop_mode:?} (chaos={chaos}): {} heap entries",
                        r.heap_entries_max
                    ));
                }
                if !chaos && r.reclaim_evictions == 0 {
                    return Err(format!(
                        "fl acceptance failed under {placement:?}/\
                         {loop_mode:?}: the notebook wave reclaimed \
                         nothing"
                    ));
                }
                let csvs = (r.placements.to_csv(), r.table.to_csv());
                match &reference {
                    None => reference = Some(csvs),
                    Some(reference) => {
                        if *reference != csvs {
                            return Err(format!(
                                "cross-mode divergence under \
                                 {placement:?}/{loop_mode:?} \
                                 (chaos={chaos}): placement or \
                                 round-series CSV differs from the \
                                 first mode"
                            ));
                        }
                    }
                }
            }
        }
    }
    // The zero-per-client-event claim: the identical schedule at 10×
    // the population must cost the identical coordinator event count.
    let small = experiments::fl_rounds::run_fl_rounds(base);
    let scaled = experiments::fl_rounds::run_fl_rounds(
        &experiments::fl_rounds::FlRoundsConfig {
            population: base.population * 10,
            ..base.clone()
        },
    );
    println!(
        "  population {} -> {} events; population {} -> {} events",
        small.population,
        small.events_processed,
        scaled.population,
        scaled.events_processed
    );
    if small.events_processed != scaled.events_processed
        || small.cycles != scaled.cycles
    {
        return Err(format!(
            "fl acceptance failed: event count depends on population \
             ({} events at {} clients vs {} events at {} clients)",
            small.events_processed,
            small.population,
            scaled.events_processed,
            scaled.population
        ));
    }
    println!(
        "check-modes OK: all 8 fl mode combinations byte-identical \
         (plain + outage); every round committed; event count \
         population-independent"
    );
    Ok(())
}

/// Run and report the fault-injection phase.
fn run_chaos(
    cfg: &experiments::chaos_stress::ChaosStressConfig,
) -> Result<(), String> {
    println!(
        "FED-STRESS --chaos: {} workers / {} burst jobs, {} rolling \
         crashes from t={}s (reboot +{}s{}), blackout on {} over \
         [{}s,{}s) (seed {}, {:?}, {:?})",
        cfg.n_workers,
        cfg.n_burst,
        cfg.n_crashes,
        cfg.crash_first_s,
        cfg.crash_reboot_after_s,
        match cfg.recrash_after_s {
            Some(s) => format!(", second tap +{s}s"),
            None => String::new(),
        },
        cfg.blackout_site,
        cfg.blackout_from_s,
        cfg.blackout_until_s,
        cfg.seed,
        cfg.placement,
        cfg.loop_mode
    );
    let started = std::time::Instant::now();
    let r = experiments::chaos_stress::run_chaos_stress(cfg);
    println!("{}", r.table.to_aligned());
    println!(
        "{} node failures / {} reboots / {} site outages; {} pods \
         evicted by fault; {} kueue fault evictions, {} recoveries \
         (mean {:.1}s, max {:.1}s), {} retry-exhausted; {} breaker \
         refusals, blackout breaker ends {:?}; {} lost workloads; {} \
         still pending; {} events ({} controller cycles) in {:.2}s wall",
        r.node_failures,
        r.node_reboots,
        r.site_outages,
        r.pods_evicted_by_fault,
        r.fault_evictions,
        r.fault_recoveries,
        r.recovery_mean_s,
        r.recovery_max_s,
        r.retry_exhausted,
        r.breaker_refusals,
        r.blackout_breaker_end,
        r.lost_workloads,
        r.pending_end,
        r.events_processed,
        r.cycles.total(),
        started.elapsed().as_secs_f64()
    );
    if let Some(v) = &r.invariant_violation {
        return Err(format!("invariant violated under chaos: {v}"));
    }
    if r.lost_workloads != 0 {
        return Err(format!(
            "{} workloads lost: faults may delay work, never drop it",
            r.lost_workloads
        ));
    }
    save(&r.table, "chaos_stress");
    save(&r.placements, "chaos_stress_placements");
    Ok(())
}

/// The chaos flavour of the CI cross-mode gate: byte-identical
/// recovery/placement CSVs across the 2×2 matrix, zero lost workloads,
/// bounded recovery time, clean accounting at every sample, and the
/// blackout site's breaker back to Closed by the horizon.
fn check_modes_chaos(
    base: &experiments::chaos_stress::ChaosStressConfig,
) -> Result<(), String> {
    use ai_infn::cluster::PlacementMode;
    use ai_infn::coordinator::LoopMode;
    use ai_infn::offload::BreakerState;
    let mut reference: Option<(String, String)> = None;
    for placement in [PlacementMode::Indexed, PlacementMode::LinearScan] {
        for loop_mode in [LoopMode::Polling, LoopMode::Reactive] {
            let cfg = experiments::chaos_stress::ChaosStressConfig {
                placement,
                loop_mode,
                ..base.clone()
            };
            let started = std::time::Instant::now();
            let r = experiments::chaos_stress::run_chaos_stress(&cfg);
            println!(
                "  {placement:?}/{loop_mode:?}: {} fault evictions, {} \
                 recoveries (max {:.1}s), {} breaker refusals, {} \
                 events, {:.2}s wall",
                r.fault_evictions,
                r.fault_recoveries,
                r.recovery_max_s,
                r.breaker_refusals,
                r.events_processed,
                started.elapsed().as_secs_f64()
            );
            if let Some(v) = &r.invariant_violation {
                return Err(format!(
                    "invariant violated under {placement:?}/{loop_mode:?}: \
                     {v}"
                ));
            }
            if r.lost_workloads != 0 {
                return Err(format!(
                    "chaos acceptance failed under {placement:?}/\
                     {loop_mode:?}: {} workloads lost",
                    r.lost_workloads
                ));
            }
            if r.fault_evictions == 0 || r.fault_recoveries == 0 {
                return Err(format!(
                    "chaos acceptance failed under {placement:?}/\
                     {loop_mode:?}: the plan evicted {} and recovered {} \
                     kueue workloads — the fault path was not exercised",
                    r.fault_evictions, r.fault_recoveries
                ));
            }
            if r.recovery_max_s > base.horizon_s / 2.0 {
                return Err(format!(
                    "chaos acceptance failed under {placement:?}/\
                     {loop_mode:?}: worst recovery {:.1}s exceeds the \
                     {:.0}s bound",
                    r.recovery_max_s,
                    base.horizon_s / 2.0
                ));
            }
            if r.blackout_breaker_end != BreakerState::Closed {
                return Err(format!(
                    "chaos acceptance failed under {placement:?}/\
                     {loop_mode:?}: {} breaker still {:?} at the horizon",
                    base.blackout_site, r.blackout_breaker_end
                ));
            }
            let csvs = (r.placements.to_csv(), r.table.to_csv());
            match &reference {
                None => reference = Some(csvs),
                Some(reference) => {
                    if *reference != csvs {
                        return Err(format!(
                            "cross-mode divergence under \
                             {placement:?}/{loop_mode:?}: placement or \
                             recovery-series CSV differs from the first \
                             mode"
                        ));
                    }
                }
            }
        }
    }
    println!(
        "check-modes OK: all 4 chaos mode combinations byte-identical; \
         zero lost workloads; recovery bounded"
    );
    Ok(())
}

/// Run and report the GPU slice-wave phase.
fn run_slices(
    cfg: &experiments::fed_stress::SliceWaveConfig,
) -> Result<(), String> {
    println!(
        "FED-STRESS --slices: {} workers, {} holders, {} notebooks, \
         {} flavors (seed {}, {:?}, {:?})",
        cfg.n_workers,
        cfg.n_holders,
        cfg.n_notebooks,
        if cfg.use_slices { "partitioned" } else { "whole-GPU" },
        cfg.seed,
        cfg.placement,
        cfg.loop_mode
    );
    let started = std::time::Instant::now();
    let r = experiments::fed_stress::run_slice_wave(cfg);
    println!("{}", r.table.to_aligned());
    println!(
        "{} wave notebooks running of {} spawned on {} MIG devices \
         (peak {}); {} partitions carved; {} evictions; {} still \
         pending; {} events ({} controller cycles) in {:.2}s wall",
        r.notebooks_running,
        r.notebooks_spawned,
        r.mig_devices,
        r.peak_coresident,
        r.slice_allocations,
        r.evictions,
        r.pending_end,
        r.events_processed,
        r.cycles.total(),
        started.elapsed().as_secs_f64()
    );
    save(&r.table, "slice_wave");
    save(&r.placements, "slice_wave_placements");
    Ok(())
}

/// The slice-wave flavour of the CI cross-mode gate: byte-identical
/// CSVs across the 2×2 matrix, plus the ≥2× co-residency acceptance
/// against the whole-GPU baseline.
fn check_modes_slices(
    base: &experiments::fed_stress::SliceWaveConfig,
) -> Result<(), String> {
    use ai_infn::cluster::PlacementMode;
    use ai_infn::coordinator::LoopMode;
    let mut reference: Option<(String, String)> = None;
    let mut slice_running = 0usize;
    for placement in [PlacementMode::Indexed, PlacementMode::LinearScan] {
        for loop_mode in [LoopMode::Polling, LoopMode::Reactive] {
            let cfg = experiments::fed_stress::SliceWaveConfig {
                placement,
                loop_mode,
                use_slices: true,
                ..base.clone()
            };
            let started = std::time::Instant::now();
            let r = experiments::fed_stress::run_slice_wave(&cfg);
            println!(
                "  {placement:?}/{loop_mode:?}: {} notebooks co-resident, \
                 {} partitions carved, {} events, {:.2}s wall",
                r.notebooks_running,
                r.slice_allocations,
                r.events_processed,
                started.elapsed().as_secs_f64()
            );
            slice_running = r.notebooks_running;
            let csvs = (r.placements.to_csv(), r.table.to_csv());
            match &reference {
                None => reference = Some(csvs),
                Some(reference) => {
                    if *reference != csvs {
                        return Err(format!(
                            "cross-mode divergence under \
                             {placement:?}/{loop_mode:?}: placement or \
                             slice-series CSV differs from the first mode"
                        ));
                    }
                }
            }
        }
    }
    // The whole-GPU baseline (indexed/default loop) for the
    // co-residency acceptance.
    let whole = experiments::fed_stress::run_slice_wave(
        &experiments::fed_stress::SliceWaveConfig {
            use_slices: false,
            placement: PlacementMode::Indexed,
            ..base.clone()
        },
    );
    println!(
        "  whole-GPU baseline: {} notebooks co-resident on {} MIG devices",
        whole.notebooks_running, whole.mig_devices
    );
    if slice_running < 2 * whole.notebooks_running.max(1) {
        return Err(format!(
            "slice-wave acceptance failed: {} co-resident notebooks vs \
             {} whole-GPU baseline (< 2×)",
            slice_running, whole.notebooks_running
        ));
    }
    println!(
        "check-modes OK: all 4 slice-wave mode combinations \
         byte-identical; co-residency {:.1}× baseline",
        slice_running as f64 / whole.notebooks_running.max(1) as f64
    );
    Ok(())
}

/// Run and report the cohort-contention quota phase.
fn run_cohort(
    cfg: &experiments::fed_stress::CohortStressConfig,
) -> Result<(), String> {
    println!(
        "FED-STRESS --cohort: {} workers, {}m jobs (seed {}, {:?}, {:?})",
        cfg.n_workers, cfg.job_cpu_m, cfg.seed, cfg.placement, cfg.loop_mode
    );
    let started = std::time::Instant::now();
    let r = experiments::fed_stress::run_cohort_contention(cfg);
    println!("{}", r.table.to_aligned());
    println!(
        "owner nominal {}m / borrower nominal {}m; burst absorbed {}‰ of \
         the idle owner quota (peak borrowed {}m); owner restored: {}; \
         borrower ≥ nominal: {}; {} reclaim evictions; {} still pending; \
         {} events ({} controller cycles) in {:.2}s wall",
        r.owner_nominal_m,
        r.borrower_nominal_m,
        r.burst_absorption_permille,
        r.peak_borrowed_m,
        r.owner_restored,
        r.borrower_at_nominal,
        r.reclaim_evictions,
        r.pending_end,
        r.events_processed,
        r.cycles.total(),
        started.elapsed().as_secs_f64()
    );
    if let Some(v) = &r.invariant_violation {
        return Err(format!("cohort invariant violated: {v}"));
    }
    save(&r.table, "cohort_stress");
    save(&r.placements, "cohort_stress_placements");
    Ok(())
}

/// The cohort flavour of the CI cross-mode gate.
fn check_modes_cohort(
    base: &experiments::fed_stress::CohortStressConfig,
) -> Result<(), String> {
    use ai_infn::cluster::PlacementMode;
    use ai_infn::coordinator::LoopMode;
    let mut reference: Option<(String, String)> = None;
    for placement in [PlacementMode::Indexed, PlacementMode::LinearScan] {
        for loop_mode in [LoopMode::Polling, LoopMode::Reactive] {
            let cfg = experiments::fed_stress::CohortStressConfig {
                placement,
                loop_mode,
                ..base.clone()
            };
            let started = std::time::Instant::now();
            let r = experiments::fed_stress::run_cohort_contention(&cfg);
            println!(
                "  {placement:?}/{loop_mode:?}: absorbed {}‰, {} reclaim \
                 evictions, {} events, {:.2}s wall",
                r.burst_absorption_permille,
                r.reclaim_evictions,
                r.events_processed,
                started.elapsed().as_secs_f64()
            );
            if let Some(v) = &r.invariant_violation {
                return Err(format!(
                    "cohort invariant violated under \
                     {placement:?}/{loop_mode:?}: {v}"
                ));
            }
            if !(r.burst_absorption_permille >= 800
                && r.owner_restored
                && r.borrower_at_nominal)
            {
                return Err(format!(
                    "cohort acceptance failed under {placement:?}/\
                     {loop_mode:?}: absorbed {}‰, owner restored {}, \
                     borrower ≥ nominal {}",
                    r.burst_absorption_permille,
                    r.owner_restored,
                    r.borrower_at_nominal
                ));
            }
            let csvs = (r.placements.to_csv(), r.table.to_csv());
            match &reference {
                None => reference = Some(csvs),
                Some(reference) => {
                    if *reference != csvs {
                        return Err(format!(
                            "cross-mode divergence under \
                             {placement:?}/{loop_mode:?}: placement or \
                             quota-series CSV differs from the first mode"
                        ));
                    }
                }
            }
        }
    }
    println!("check-modes OK: all 4 cohort mode combinations byte-identical");
    Ok(())
}

/// The CI cross-mode gate: every (placement × loop) combination of the
/// given scenario must emit byte-identical placement/phase CSVs.
fn check_modes(
    base: &experiments::fed_stress::FedStressConfig,
) -> Result<(), String> {
    use ai_infn::cluster::PlacementMode;
    use ai_infn::coordinator::LoopMode;
    let mut reference: Option<(String, String)> = None;
    for placement in [PlacementMode::Indexed, PlacementMode::LinearScan] {
        for loop_mode in [LoopMode::Polling, LoopMode::Reactive] {
            let cfg = experiments::fed_stress::FedStressConfig {
                placement,
                loop_mode,
                ..base.clone()
            };
            let started = std::time::Instant::now();
            let r = experiments::fed_stress::run_fed_stress(&cfg);
            println!(
                "  {placement:?}/{loop_mode:?}: {} events, {} cycles, \
                 {:.2}s wall",
                r.events_processed,
                r.cycles.total(),
                started.elapsed().as_secs_f64()
            );
            let csvs = (r.placements.to_csv(), r.table.to_csv());
            match &reference {
                None => reference = Some(csvs),
                Some(reference) => {
                    if *reference != csvs {
                        return Err(format!(
                            "cross-mode divergence under \
                             {placement:?}/{loop_mode:?}: placement or \
                             time-series CSV differs from the first mode"
                        ));
                    }
                }
            }
        }
    }
    println!("check-modes OK: all 4 mode combinations byte-identical");
    Ok(())
}

/// Run and report the xl sharded-core phase.
fn run_xl(
    cfg: &experiments::fed_stress::XlStressConfig,
) -> Result<(), String> {
    println!(
        "FED-STRESS --xl: {} nodes over {} sites / {} storm pods / \
         {} shards × {} workers (seed {}, {:?}, {:?})",
        cfg.n_nodes,
        cfg.n_sites,
        cfg.n_pods,
        cfg.n_shards,
        cfg.workers,
        cfg.seed,
        cfg.placement,
        cfg.loop_mode
    );
    let started = std::time::Instant::now();
    let r = experiments::fed_stress::run_xl_stress(cfg);
    println!("{}", r.table.to_aligned());
    println!(
        "storm placed {}/{} pods across {} shards; Kueue tail admitted \
         {} local, {} still pending; {} events ({} cycles); placement \
         digest {:016x}; {:.2}s wall",
        r.storm_placed,
        r.storm_pods,
        r.n_shards,
        r.admitted_local,
        r.pending_end,
        r.events_processed,
        r.cycles.total(),
        r.placement_digest,
        started.elapsed().as_secs_f64()
    );
    // Stable machine-greppable line: CI diffs this across `--threads`
    // (and `--commit-threads`) invocations.
    println!("placement-digest: {:016x}", r.placement_digest);
    save(&r.table, "fed_stress_xl");
    Ok(())
}

/// The xl CI cross-mode gate: every (placement × loop) combination must
/// agree on the placement digest and the tail time-series, every
/// (scatter, commit) worker-width combination must reproduce the same
/// digest, and the reactive loop must record strictly fewer per-shard
/// scheduler visits than polling (the zone-scoping acceptance). The
/// digest stands in for the per-pod CSV, which is deliberately not
/// materialised at xl scale.
fn check_modes_xl(
    base: &experiments::fed_stress::XlStressConfig,
) -> Result<(), String> {
    use ai_infn::cluster::PlacementMode;
    use ai_infn::coordinator::LoopMode;
    let mut reference: Option<(u64, String)> = None;
    let mut visits: Vec<(LoopMode, u64)> = Vec::new();
    for placement in [PlacementMode::Indexed, PlacementMode::LinearScan] {
        for loop_mode in [LoopMode::Polling, LoopMode::Reactive] {
            let cfg = experiments::fed_stress::XlStressConfig {
                placement,
                loop_mode,
                ..base.clone()
            };
            let started = std::time::Instant::now();
            let r = experiments::fed_stress::run_xl_stress(&cfg);
            println!(
                "  {placement:?}/{loop_mode:?}: placed {}/{}, digest \
                 {:016x}, {} shard visits / {} skips, {:.2}s wall",
                r.storm_placed,
                r.storm_pods,
                r.placement_digest,
                r.shard_visits_total,
                r.shard_skips_total,
                started.elapsed().as_secs_f64()
            );
            if placement == PlacementMode::Indexed {
                visits.push((loop_mode, r.shard_visits_total));
            }
            let got = (r.placement_digest, r.table.to_csv());
            match &reference {
                None => reference = Some(got),
                Some(reference) => {
                    if *reference != got {
                        return Err(format!(
                            "cross-mode divergence under \
                             {placement:?}/{loop_mode:?}: placement \
                             digest or tail time-series differs from \
                             the first mode"
                        ));
                    }
                }
            }
        }
    }
    let (ref_digest, _) = reference.as_ref().expect("matrix ran");
    // Worker sweep: scatter widths 1/2/4/8, the parallel commit at
    // every width, and the serial-commit baseline at full scatter.
    for (workers, commit_workers) in
        [(1usize, 0usize), (2, 0), (4, 0), (8, 0), (8, 1)]
    {
        let cfg = experiments::fed_stress::XlStressConfig {
            workers,
            commit_workers,
            ..base.clone()
        };
        let started = std::time::Instant::now();
        let r = experiments::fed_stress::run_xl_stress(&cfg);
        println!(
            "  workers={workers} commit={commit_workers}: digest {:016x}, \
             {:.2}s wall",
            r.placement_digest,
            started.elapsed().as_secs_f64()
        );
        if r.placement_digest != *ref_digest {
            return Err(format!(
                "worker-count divergence at workers={workers} \
                 commit_workers={commit_workers}: digest {:016x} != \
                 {:016x}",
                r.placement_digest, ref_digest
            ));
        }
    }
    // Zone-scoping acceptance: the site-skewed refused tail must make
    // the reactive loop's per-shard visit total strictly smaller.
    let poll_v = visits
        .iter()
        .find(|(m, _)| *m == LoopMode::Polling)
        .map(|(_, v)| *v)
        .unwrap_or(0);
    let react_v = visits
        .iter()
        .find(|(m, _)| *m == LoopMode::Reactive)
        .map(|(_, v)| *v)
        .unwrap_or(0);
    if react_v >= poll_v {
        return Err(format!(
            "zone scoping did not prune: {react_v} reactive shard \
             visits vs {poll_v} polling"
        ));
    }
    println!("placement-digest: {ref_digest:016x}");
    println!(
        "check-modes OK: 4 mode combinations + 5 worker widths \
         digest-identical; reactive visited {react_v} shard scans vs \
         {poll_v} polling"
    );
    Ok(())
}

fn cmd_flashsim(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("flashsim", "run the real PJRT payload")
        .opt("events", "100000", "events to generate")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("seed", "1", "PRNG seed");
    let p = cmd.parse(args)?;
    let fs = ai_infn::runtime::FlashSim::load(p.str("artifacts"))
        .map_err(|e| format!("{e:#}"))?;
    println!(
        "flash-sim payload on PJRT ({}), batch={} …",
        fs.runtime.platform(),
        fs.runtime.meta.batch_gen
    );
    let mut rng = ai_infn::util::rng::Rng::new(p.u64("seed")?);
    let (events, secs, rate) = fs
        .run_job(p.u64("events")?, &mut rng)
        .map_err(|e| format!("{e:#}"))?;
    println!(
        "generated {events} events in {secs:.2}s → {rate:.0} events/s"
    );
    Ok(())
}

fn cmd_demo() -> Result<(), String> {
    println!("=== AI_INFN platform demo ===\n");
    cmd_inventory();
    println!("\n--- Figure 2 (reduced: 400 jobs, 75 min horizon) ---\n");
    cmd_fig2(&["--jobs".into(), "400".into(), "--horizon".into(), "4500".into()])?;
    println!("\n--- storage spectrum ---\n");
    cmd_storage();
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (sub, rest) = match args.split_first() {
        Some((s, r)) => (s.as_str(), r.to_vec()),
        None => ("help", vec![]),
    };
    let result = match sub {
        "inventory" => {
            cmd_inventory();
            Ok(())
        }
        "fig2" => cmd_fig2(&rest),
        "storage" => {
            cmd_storage();
            Ok(())
        }
        "envs" => {
            cmd_envs();
            Ok(())
        }
        "eviction" => cmd_eviction(&rest),
        "crossover" => cmd_crossover(&rest),
        "vm-vs-platform" => cmd_vm_vs_platform(&rest),
        "fed-stress" => cmd_fed_stress(&rest),
        "flashsim" => cmd_flashsim(&rest),
        "demo" => cmd_demo(),
        _ => {
            println!(
                "ainfn — AI_INFN platform reproduction\n\n\
                 subcommands:\n\
                 \x20 inventory        §2 server table (TAB1)\n\
                 \x20 fig2             Figure 2 scalability test\n\
                 \x20 storage          §3 I/O spectrum (STO1)\n\
                 \x20 envs             conda vs apptainer (ENV1)\n\
                 \x20 eviction         Kueue contention (KUE1)\n\
                 \x20 crossover        offload effectiveness (OFF1)\n\
                 \x20 vm-vs-platform   §2 motivation replay (MOT1)\n\
                 \x20 fed-stress       federation stress (indexed scheduling)\n\
                 \x20 flashsim         run the real PJRT payload\n\
                 \x20 demo             guided tour"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
