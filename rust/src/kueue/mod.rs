//! Kueue-like batch queueing controller (§4).
//!
//! "Users are allowed to scale beyond their notebook instance by
//! creating Kubernetes jobs, enqueued and assigned to either local or
//! remote resources by the Kueue controller. Kueue is designed to use
//! local resources in an opportunistic way, configuring the running
//! batch jobs to be immediately evicted in case new notebook instances
//! are spawned pushing the cluster in a condition of resource
//! contention. ... Kueue may then assign jobs marked as *compatible with
//! offloading* to *virtual nodes*."
//!
//! Semantics implemented: LocalQueue → ClusterQueue with nominal quotas,
//! FIFO admission with deterministic order, opportunistic local
//! placement of batch workloads, preemption-and-requeue on notebook
//! contention, and virtual-node assignment for offload-compatible
//! workloads (preferring local capacity when available).

use std::collections::{BTreeMap, VecDeque};

use crate::cluster::{
    Cluster, NodeId, PlacementMode, PodId, PodPhase, Scheduler,
    ScoringPolicy,
};
use crate::sim::Time;

/// Workload identity (one batch job = one pod in this platform).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct WorkloadId(pub u64);

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadState {
    Queued,
    Admitted,
    Finished,
    Failed,
}

#[derive(Clone, Debug)]
pub struct Workload {
    pub id: WorkloadId,
    pub pod: PodId,
    pub queue: String,
    pub owner: String,
    pub offload_compatible: bool,
    pub state: WorkloadState,
    pub submitted_at: Time,
    pub admitted_at: Option<Time>,
    pub finished_at: Option<Time>,
    /// Which node admitted it (for the Fig. 2 series), virtual or
    /// physical — an interned handle; resolve via `Cluster::name_of`.
    pub assigned_node: Option<NodeId>,
    pub requeues: u32,
}

/// A ClusterQueue: quota in whole CPUs/GPUs over the *local* farm.
#[derive(Clone, Debug)]
pub struct ClusterQueue {
    pub name: String,
    /// Max local CPU millicores admitted concurrently (None = opportunistic,
    /// bounded only by actual free capacity).
    pub cpu_quota_m: Option<u64>,
    pub gpu_quota: Option<u32>,
    /// Admitted local usage.
    pub used_cpu_m: u64,
    pub used_gpus: u32,
}

impl ClusterQueue {
    pub fn opportunistic(name: &str) -> Self {
        ClusterQueue {
            name: name.to_string(),
            cpu_quota_m: None,
            gpu_quota: None,
            used_cpu_m: 0,
            used_gpus: 0,
        }
    }

    pub fn with_quota(name: &str, cpu_m: u64, gpus: u32) -> Self {
        ClusterQueue {
            name: name.to_string(),
            cpu_quota_m: Some(cpu_m),
            gpu_quota: Some(gpus),
            used_cpu_m: 0,
            used_gpus: 0,
        }
    }

    fn has_room(&self, cpu_m: u64, gpus: u32) -> bool {
        self.cpu_quota_m.map_or(true, |q| self.used_cpu_m + cpu_m <= q)
            && self.gpu_quota.map_or(true, |q| self.used_gpus + gpus <= q)
    }
}

/// The controller.
#[derive(Debug, Default)]
pub struct Kueue {
    queues: BTreeMap<String, ClusterQueue>,
    workloads: BTreeMap<WorkloadId, Workload>,
    pending: VecDeque<WorkloadId>,
    /// Reverse map: which workload owns a pod. Maintained by submit and
    /// respawn so the coordinator's reconcile path resolves a finished
    /// pod in O(log n) instead of scanning every workload.
    pod_owner: BTreeMap<PodId, WorkloadId>,
    next_id: u64,
    /// Round-robin cursor over virtual nodes.
    vnode_rr: usize,
    /// Admission stats for the experiments.
    pub n_admitted_local: u64,
    pub n_admitted_virtual: u64,
    pub n_evictions: u64,
    /// Edge signal for the reactive coordinator: set on every
    /// pending-set or quota delta (submit, requeue, respawn, finish) —
    /// exactly the transitions after which an admission cycle could do
    /// new work. Consumed by [`Kueue::take_dirty`].
    dirty: bool,
}

impl Kueue {
    pub fn new() -> Self {
        let mut k = Kueue::default();
        // The platform's default queue is opportunistic local batch.
        k.add_queue(ClusterQueue::opportunistic("local-batch"));
        k
    }

    pub fn add_queue(&mut self, q: ClusterQueue) {
        self.queues.insert(q.name.clone(), q);
    }

    pub fn queue(&self, name: &str) -> Option<&ClusterQueue> {
        self.queues.get(name)
    }

    /// Enqueue a workload for an already-created (Pending) pod.
    pub fn submit(
        &mut self,
        pod: PodId,
        queue: &str,
        owner: &str,
        offload_compatible: bool,
        now: Time,
    ) -> Result<WorkloadId, String> {
        if !self.queues.contains_key(queue) {
            return Err(format!("no such queue {queue}"));
        }
        self.next_id += 1;
        let id = WorkloadId(self.next_id);
        self.workloads.insert(
            id,
            Workload {
                id,
                pod,
                queue: queue.to_string(),
                owner: owner.to_string(),
                offload_compatible,
                state: WorkloadState::Queued,
                submitted_at: now,
                admitted_at: None,
                finished_at: None,
                assigned_node: None,
                requeues: 0,
            },
        );
        self.pod_owner.insert(pod, id);
        self.pending.push_back(id);
        self.dirty = true;
        Ok(id)
    }

    /// Consume the pending-set/quota edge signal (see the `dirty`
    /// field). The reactive coordinator calls this after every event to
    /// decide whether an admission cycle is worth scheduling.
    pub fn take_dirty(&mut self) -> bool {
        std::mem::take(&mut self.dirty)
    }

    pub fn workload(&self, id: WorkloadId) -> Option<&Workload> {
        self.workloads.get(&id)
    }

    pub fn workloads(&self) -> impl Iterator<Item = &Workload> {
        self.workloads.values()
    }

    /// The workload owning `pod` (its current incarnation), if any.
    pub fn workload_of_pod(&self, pod: PodId) -> Option<WorkloadId> {
        self.pod_owner.get(&pod).copied()
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    /// Pending workload ids in queue order (front first) — exposed for
    /// the seniority invariant tests.
    pub fn pending_ids(&self) -> Vec<WorkloadId> {
        self.pending.iter().copied().collect()
    }

    /// Round-robin over virtual nodes that admit and fit the pod.
    ///
    /// Candidates are put in node-NAME order in both modes: the linear
    /// scan iterates the cluster's name-ordered node walk, while the
    /// index's virtual set is id-ordered (ids are minted in insertion
    /// order) and is re-sorted through the interner's name table. The
    /// round-robin cursor therefore lands on the same site either way —
    /// event ordering is mode-independent and byte-compatible with the
    /// string-keyed core.
    fn pick_virtual_node(
        &mut self,
        cluster: &Cluster,
        scheduler: &Scheduler,
        pod: PodId,
    ) -> Option<NodeId> {
        let admits = |n: &crate::cluster::Node| {
            !scheduler.cordoned.contains(n.name.as_str())
                && cluster
                    .pod(pod)
                    .map(|p| {
                        p.spec.tolerates(&n.taints)
                            && n.can_fit(&p.spec.resources)
                            && p.spec
                                .node_selector
                                .as_deref()
                                .map_or(true, |s| s == n.name)
                    })
                    .unwrap_or(false)
        };
        let candidates: Vec<NodeId> = match scheduler.mode {
            // The seed's scan: every node, filtered down to virtuals.
            PlacementMode::LinearScan => cluster
                .nodes_with_ids()
                .filter(|&(_, n)| n.virtual_node && admits(n))
                .map(|(id, _)| id)
                .collect(),
            // Indexed: only the (few) registered virtual nodes.
            PlacementMode::Indexed => {
                let mut v: Vec<NodeId> = cluster
                    .index()
                    .virtual_nodes()
                    .filter(|&id| {
                        cluster.node_by_id(id).map_or(false, |n| admits(n))
                    })
                    .collect();
                v.sort_by(|&a, &b| cluster.name_of(a).cmp(cluster.name_of(b)));
                v
            }
        };
        if candidates.is_empty() {
            return None;
        }
        let pick = candidates[self.vnode_rr % candidates.len()];
        self.vnode_rr += 1;
        Some(pick)
    }

    /// One admission cycle: try to place each pending workload, local
    /// capacity first, then (if offload-compatible) a virtual node.
    /// Returns workloads admitted this cycle.
    pub fn admission_cycle(
        &mut self,
        cluster: &mut Cluster,
        scheduler: &Scheduler,
        now: Time,
    ) -> Vec<WorkloadId> {
        let mut admitted = Vec::new();
        let mut still_pending = VecDeque::new();

        while let Some(id) = self.pending.pop_front() {
            // No `queue.clone()` here: every admission cycle walks the
            // whole pending set, so a per-workload name clone is a hot
            // allocation. The queue map is only indexed through a fresh
            // `&self.workloads[&id].queue` borrow at each use instead.
            let (pod_id, offloadable) = {
                let w = &self.workloads[&id];
                (w.pod, w.offload_compatible)
            };
            let (cpu_m, gpus) = match cluster.pod(pod_id) {
                Some(p) if p.phase == PodPhase::Pending => {
                    (p.spec.resources.cpu_m, p.spec.resources.gpus)
                }
                _ => {
                    // Pod vanished or already handled; drop the workload.
                    self.workloads.get_mut(&id).unwrap().state =
                        WorkloadState::Failed;
                    continue;
                }
            };

            let queue_ok =
                self.queues[&self.workloads[&id].queue].has_room(cpu_m, gpus);
            let mut placed: Option<NodeId> = None;
            if queue_ok {
                // Local first (opportunistic use of the farm); batch
                // spreads to minimise the eviction blast radius. The
                // unclassified try_place keeps a failed attempt cheap
                // under the index (a pending workload just stays queued).
                if let Some(node) = scheduler.try_place(
                    cluster,
                    pod_id,
                    ScoringPolicy::Spread,
                    false,
                ) {
                    if cluster.bind_to(pod_id, node).is_ok() {
                        placed = Some(node);
                    }
                }
                // Then the virtual nodes, round-robin across sites with
                // room — every federated site ramps concurrently, which
                // is how the paper's Fig. 2 test drove the plugins.
                if placed.is_none() && offloadable {
                    if let Some(node) =
                        self.pick_virtual_node(cluster, scheduler, pod_id)
                    {
                        if cluster.bind_to(pod_id, node).is_ok() {
                            placed = Some(node);
                        }
                    }
                }
            }

            match placed {
                Some(node) => {
                    let is_virtual = cluster
                        .node_by_id(node)
                        .map(|n| n.virtual_node)
                        .unwrap_or(false);
                    if is_virtual {
                        self.n_admitted_virtual += 1;
                    } else {
                        self.n_admitted_local += 1;
                        let q = self.queues.get_mut(&self.workloads[&id].queue).unwrap();
                        q.used_cpu_m += cpu_m;
                        q.used_gpus += gpus;
                    }
                    let w = self.workloads.get_mut(&id).unwrap();
                    w.state = WorkloadState::Admitted;
                    w.admitted_at = Some(now);
                    w.assigned_node = Some(node);
                    admitted.push(id);
                }
                None => still_pending.push_back(id),
            }
        }
        self.pending = still_pending;
        admitted
    }

    /// §4 contention path: a notebook pod cannot fit → evict enough
    /// batch pods (per the scheduler's preemption plan), requeue their
    /// workloads, and bind the notebook. Returns evicted workload ids.
    pub fn make_room_for_notebook(
        &mut self,
        cluster: &mut Cluster,
        scheduler: &Scheduler,
        notebook_pod: PodId,
    ) -> Result<(NodeId, Vec<WorkloadId>), String> {
        let (node, victims) = scheduler
            .plan_preemption(cluster, notebook_pod)
            .ok_or("no preemption plan frees enough resources")?;
        let mut evicted = Vec::new();
        for pod in victims {
            cluster.evict(pod)?;
            self.n_evictions += 1;
            // Requeue the owning workload (if the pod is Kueue-managed).
            let owner = self.pod_owner.get(&pod).copied();
            if let Some(w) = owner
                .and_then(|wid| self.workloads.get_mut(&wid))
                .filter(|w| w.pod == pod && w.state == WorkloadState::Admitted)
            {
                // Release local quota.
                if let Some(p) = cluster.pod(pod) {
                    let q = self.queues.get_mut(&w.queue).unwrap();
                    q.used_cpu_m =
                        q.used_cpu_m.saturating_sub(p.spec.resources.cpu_m);
                    q.used_gpus =
                        q.used_gpus.saturating_sub(p.spec.resources.gpus);
                }
                w.state = WorkloadState::Queued;
                w.admitted_at = None;
                w.assigned_node = None;
                w.requeues += 1;
                evicted.push(w.id);
            }
        }
        // Requeue evicted workloads at the FRONT (they keep seniority),
        // preserving their original relative order.
        for id in evicted.iter().rev() {
            // The evicted pod is terminal; the owner resubmits a clone.
            self.pending.push_front(*id);
        }
        if !evicted.is_empty() {
            self.dirty = true;
        }
        cluster.bind_to(notebook_pod, node)?;
        Ok((node, evicted))
    }

    /// Mark a workload finished (its pod completed) and release quota.
    pub fn finish(
        &mut self,
        cluster: &Cluster,
        id: WorkloadId,
        ok: bool,
        now: Time,
    ) -> Result<(), String> {
        let w = self
            .workloads
            .get_mut(&id)
            .ok_or_else(|| format!("no workload {id:?}"))?;
        if w.state != WorkloadState::Admitted {
            return Err(format!("workload {id:?} not admitted"));
        }
        let was_local = w
            .assigned_node
            .and_then(|n| cluster.node_by_id(n))
            .map(|n| !n.virtual_node)
            .unwrap_or(false);
        if was_local {
            if let Some(p) = cluster.pod(w.pod) {
                let q = self.queues.get_mut(&w.queue).unwrap();
                q.used_cpu_m =
                    q.used_cpu_m.saturating_sub(p.spec.resources.cpu_m);
                q.used_gpus = q.used_gpus.saturating_sub(p.spec.resources.gpus);
            }
        }
        w.state = if ok { WorkloadState::Finished } else { WorkloadState::Failed };
        w.finished_at = Some(now);
        // Quota (if local) was released above; pending workloads in the
        // same queue may now fit.
        self.dirty = true;
        Ok(())
    }

    /// Re-create pods for requeued workloads whose pods are terminal
    /// (eviction kills the pod; Kueue resubmits a fresh one).
    pub fn respawn_evicted_pods(&mut self, cluster: &mut Cluster) {
        let ids: Vec<WorkloadId> = self.pending.iter().copied().collect();
        for id in ids {
            let w = self.workloads.get_mut(&id).unwrap();
            let needs_new_pod = cluster
                .pod(w.pod)
                .map(|p| p.phase == PodPhase::Evicted)
                .unwrap_or(false);
            if needs_new_pod {
                let spec = cluster.pod(w.pod).unwrap().spec.clone();
                let new_pod = cluster.create_pod(spec);
                self.pod_owner.remove(&w.pod);
                self.pod_owner.insert(new_pod, id);
                w.pod = new_pod;
                self.dirty = true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Node, PodSpec, Resources, ScheduleError};
    use crate::util::bytes::GIB;

    fn farm() -> (Cluster, Scheduler, Kueue) {
        let mut c = Cluster::new();
        c.add_node(Node::physical("n1", 8_000, 32 * GIB, GIB, &[]));
        (c, Scheduler::new(), Kueue::new())
    }

    fn batch_pod(c: &mut Cluster, cpu_m: u64) -> PodId {
        c.create_pod(PodSpec::batch("u", Resources::cpu_mem(cpu_m, GIB), "job"))
    }

    #[test]
    fn fifo_admission_until_capacity() {
        let (mut c, s, mut k) = farm();
        let mut ids = Vec::new();
        for _ in 0..5 {
            let p = batch_pod(&mut c, 3_000); // node fits 2 of these
            ids.push(k.submit(p, "local-batch", "u", false, 0.0).unwrap());
        }
        let admitted = k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(admitted, vec![ids[0], ids[1]]);
        assert_eq!(k.pending_count(), 3);
        assert_eq!(k.n_admitted_local, 2);
    }

    #[test]
    fn quota_limits_admission_even_with_capacity() {
        let (mut c, s, mut k) = farm();
        k.add_queue(ClusterQueue::with_quota("capped", 3_000, 0));
        let p1 = batch_pod(&mut c, 2_000);
        let p2 = batch_pod(&mut c, 2_000);
        k.submit(p1, "capped", "u", false, 0.0).unwrap();
        k.submit(p2, "capped", "u", false, 0.0).unwrap();
        let admitted = k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(admitted.len(), 1); // quota 3000m, each needs 2000m
    }

    #[test]
    fn notebook_contention_evicts_batch_and_requeues() {
        let (mut c, s, mut k) = farm();
        // Fill the node with batch.
        let p1 = batch_pod(&mut c, 4_000);
        let p2 = batch_pod(&mut c, 4_000);
        let w1 = k.submit(p1, "local-batch", "u", false, 0.0).unwrap();
        let w2 = k.submit(p2, "local-batch", "u", false, 0.0).unwrap();
        k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(c.running_pods(), 2);

        // Notebook arrives; no room.
        let nb = c.create_pod(PodSpec::notebook(
            "rosa",
            Resources::cpu_mem(6_000, 8 * GIB),
        ));
        assert!(matches!(
            s.place(&c, nb, ScoringPolicy::BinPack),
            Err(ScheduleError::NoCapacity)
        ));
        let (_, evicted) =
            k.make_room_for_notebook(&mut c, &s, nb).unwrap();
        assert!(!evicted.is_empty());
        assert_eq!(c.pod(nb).unwrap().phase, PodPhase::Running);
        assert_eq!(k.n_evictions as usize, evicted.len());
        // Evicted workloads are queued again with seniority.
        assert!(evicted.iter().all(|id| {
            k.workload(*id).unwrap().state == WorkloadState::Queued
        }));
        assert!(k.pending.front().map(|f| evicted.contains(f)).unwrap_or(false));
        let _ = (w1, w2);
        c.check_accounting().unwrap();
    }

    #[test]
    fn respawn_creates_fresh_pods_for_evicted() {
        let (mut c, s, mut k) = farm();
        let p1 = batch_pod(&mut c, 8_000);
        let w1 = k.submit(p1, "local-batch", "u", false, 0.0).unwrap();
        k.admission_cycle(&mut c, &s, 1.0);
        let nb = c.create_pod(PodSpec::notebook(
            "rosa",
            Resources::cpu_mem(2_000, GIB),
        ));
        k.make_room_for_notebook(&mut c, &s, nb).unwrap();
        k.respawn_evicted_pods(&mut c);
        let new_pod = k.workload(w1).unwrap().pod;
        assert_ne!(new_pod, p1);
        assert_eq!(c.pod(new_pod).unwrap().phase, PodPhase::Pending);
        // And it can be admitted once capacity allows.
        c.complete(nb).unwrap();
        let admitted = k.admission_cycle(&mut c, &s, 2.0);
        assert_eq!(admitted, vec![w1]);
    }

    #[test]
    fn finish_releases_quota() {
        let (mut c, s, mut k) = farm();
        k.add_queue(ClusterQueue::with_quota("capped", 4_000, 0));
        let p1 = batch_pod(&mut c, 4_000);
        let w1 = k.submit(p1, "capped", "u", false, 0.0).unwrap();
        k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(k.queue("capped").unwrap().used_cpu_m, 4_000);
        c.complete(p1).unwrap();
        k.finish(&c, w1, true, 10.0).unwrap();
        assert_eq!(k.queue("capped").unwrap().used_cpu_m, 0);
        assert_eq!(
            k.workload(w1).unwrap().state,
            WorkloadState::Finished
        );
    }

    #[test]
    fn offload_compatible_workload_reaches_virtual_node_when_local_full() {
        let (mut c, s, mut k) = farm();
        c.add_node(Node::virtual_node("vk-leonardo", "leonardo", 1_000_000, 1024 * GIB));
        // Fill local.
        let filler = batch_pod(&mut c, 8_000);
        k.submit(filler, "local-batch", "u", false, 0.0).unwrap();
        k.admission_cycle(&mut c, &s, 0.5);
        // Offload-compatible job: tolerates virtual nodes.
        let mut spec = PodSpec::batch("u", Resources::cpu_mem(4_000, GIB), "fs");
        spec.offload_compatible = true;
        spec.tolerations.push("interlink.virtual-node".into());
        let p = c.create_pod(spec);
        let w = k.submit(p, "local-batch", "u", true, 1.0).unwrap();
        let admitted = k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(admitted, vec![w]);
        assert_eq!(
            k.workload(w).unwrap().assigned_node.map(|n| c.name_of(n)),
            Some("vk-leonardo")
        );
        assert_eq!(k.n_admitted_virtual, 1);
        // Non-offloadable job stays pending.
        let p2 = batch_pod(&mut c, 4_000);
        k.submit(p2, "local-batch", "u", false, 2.0).unwrap();
        assert!(k.admission_cycle(&mut c, &s, 2.0).is_empty());
        assert_eq!(k.pending_count(), 1);
    }

    #[test]
    fn requeued_workloads_keep_seniority_under_indexed_path() {
        let (mut c, s, mut k) = farm();
        assert_eq!(s.mode, crate::cluster::PlacementMode::Indexed);
        // Two admitted workloads fill the node; two more wait behind.
        let mut wls = Vec::new();
        for _ in 0..4 {
            let p = batch_pod(&mut c, 4_000);
            wls.push(k.submit(p, "local-batch", "u", false, 0.0).unwrap());
        }
        k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(k.pending_ids(), vec![wls[2], wls[3]]);
        // Notebook contention evicts both admitted workloads: they must
        // re-enter at the FRONT, in their original relative order.
        let nb = c.create_pod(PodSpec::notebook(
            "rosa",
            Resources::cpu_mem(8_000, 8 * GIB),
        ));
        let (_, evicted) = k.make_room_for_notebook(&mut c, &s, nb).unwrap();
        assert_eq!(evicted, vec![wls[0], wls[1]]);
        assert_eq!(k.pending_ids(), vec![wls[0], wls[1], wls[2], wls[3]]);
        // After respawn + capacity returning, the oldest admits first
        // and the pod→workload map tracks the fresh pod.
        k.respawn_evicted_pods(&mut c);
        for w in [wls[0], wls[1]] {
            let pod = k.workload(w).unwrap().pod;
            assert_eq!(k.workload_of_pod(pod), Some(w));
        }
        c.complete(nb).unwrap();
        let admitted = k.admission_cycle(&mut c, &s, 2.0);
        assert_eq!(admitted, vec![wls[0], wls[1]]);
        c.check_index().unwrap();
    }

    #[test]
    fn submit_to_unknown_queue_fails() {
        let (mut c, _, mut k) = farm();
        let p = batch_pod(&mut c, 1_000);
        assert!(k.submit(p, "nope", "u", false, 0.0).is_err());
    }
}
