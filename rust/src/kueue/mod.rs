//! Kueue-like batch queueing controller (§4).
//!
//! "Users are allowed to scale beyond their notebook instance by
//! creating Kubernetes jobs, enqueued and assigned to either local or
//! remote resources by the Kueue controller. Kueue is designed to use
//! local resources in an opportunistic way, configuring the running
//! batch jobs to be immediately evicted in case new notebook instances
//! are spawned pushing the cluster in a condition of resource
//! contention. ... Kueue may then assign jobs marked as *compatible with
//! offloading* to *virtual nodes*."
//!
//! Semantics implemented: LocalQueue → ClusterQueue with nominal quotas
//! grouped into borrow/reclaim [`Cohort`]s, deterministic pipelined
//! admission, opportunistic local placement of batch workloads,
//! preemption-and-requeue on notebook contention, and virtual-node
//! assignment for offload-compatible workloads (preferring local
//! capacity when available).
//!
//! ## The quota tree
//!
//! Quota is a two-level tree: [`ClusterQueue`]s carry a nominal
//! [`QuotaVec`] (CPU millicores, whole GPUs, and per-GPU-model
//! slice-weighted compute units — see `kueue::quota`'s module docs;
//! `None` = opportunistic), and [`Cohort`]s group queues whose idle
//! nominal quota is mutually borrowable, bounded by per-queue
//! `borrowing_limit` / `lending_limit` vectors. The per-model
//! dimensions are what let a cohort ration "A100-equivalents"
//! separately from T4s: a carved 1g.5gb partition costs 1 of the
//! A100's 7 units, so fractional tenants and whole-device tenants
//! draw down the same entitlement. The invariant (checked from
//! scratch by [`Kueue::check_cohort_invariants`]) is component-wise
//! per cohort: `Σ borrowed ≤ Σ lendable`, which implies
//! `Σ used ≤ Σ nominal`. Only *local* admissions consume quota —
//! virtual-node offloads ride on remote capacity.
//!
//! ## The admission pipeline
//!
//! [`Kueue::admission_cycle`] is an explicit five-stage pipeline:
//!
//! 1. **snapshot** — per-queue dominant-resource shares (exact
//!    rationals, no floats) and the set of *starved* cohorts (a cohort
//!    with a pending workload its queue is nominally entitled to);
//! 2. **order** — candidates sorted by their queue's share, seniority
//!    (FIFO) within equal shares, so the starving queue goes first and
//!    a single-queue setup degrades to the seed's pure FIFO;
//! 3. **admit within nominal** — local first, then (for offloadable
//!    workloads) virtual nodes; a workload whose queue is within
//!    nominal but whose cohort is exhausted by borrowers may still
//!    offload (remote capacity consumes no cohort quota);
//! 4. **admit by borrowing** — local-only, skipped entirely for
//!    starved cohorts (a borrower never leapfrogs a starving owner);
//! 5. **plan reclaim** — a queue under its nominal quota whose
//!    admission stage 3 could not serve evicts the most-junior
//!    borrowing workloads in its cohort
//!    ([`PreemptReason::ReclaimBorrowed`], distinct from the §4
//!    notebook path): first a physical-reachability guard (no eviction
//!    for a pod that could not place even after evicting every
//!    candidate), then the junior-first victim set that makes the
//!    admission cohort-feasible — each victim must repay a blocked
//!    quota dimension, and the whole set is computed up front so
//!    quota feasibility too must be reachable before anything dies —
//!    then,
//!    if the pod still has no physical slot, a targeted single-node
//!    plan via [`crate::cluster::Scheduler::plan_reclaim`]. The
//!    junior-first candidate list is computed **once per (cohort,
//!    cycle)** and maintained incrementally as evictions consume it,
//!    so a reclaim wave pays one scan per cycle rather than one per
//!    starving workload. Evicted
//!    borrowers are requeued with seniority and their pods respawned,
//!    exactly like notebook preemption; a cycle that admits work but
//!    leaves workloads pending re-raises the dirty edge, since serving
//!    an owner un-freezes its cohort for borrowers the same cycle
//!    passed over.
//!
//! Every stage reads deterministic state and places through the
//! mode-parity scheduler APIs, so admission decisions stay
//! byte-identical across `{Indexed, LinearScan} × {Polling, Reactive}`
//! (golden-tested in `experiments::fed_stress`).
//!
//! ## Zone-scoped admission (PR-9)
//!
//! Under the reactive loop the cycle additionally *prunes by shard*:
//! each workload remembers the epoch of its last exhaustive placement
//! refusal ([`Workload::refused_epoch`]), each shard remembers the
//! epoch of its last capacity edge ([`Kueue::note_capacity_edges`]),
//! and a refused workload re-searches only shards edged since its
//! refusal — skipping the search outright when none is. The pruning is
//! exact (capacity consumption never turns a refusal into an
//! admission, and every freeing path raises a shard-hinted edge), so
//! the cross-mode byte-equality above is *preserved*, not relaxed:
//! polling keeps `shard_scoped = false` and remains the level-
//! triggered oracle that visits every shard
//! (`rust/tests/shard_commit_prop.rs` pins the matrix).

pub mod quota;

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::cluster::{
    Cluster, NodeId, PlacementMode, PodId, PodPhase, PreemptReason,
    Scheduler, ScoringPolicy, ShardSet,
};
use crate::sim::Time;

pub use quota::{Cohort, CohortUsage, QuotaVec, Share};

/// Workload identity (one batch job = one pod in this platform).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct WorkloadId(pub u64);

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadState {
    Queued,
    Admitted,
    Finished,
    Failed,
}

#[derive(Clone, Debug)]
pub struct Workload {
    pub id: WorkloadId,
    pub pod: PodId,
    pub queue: String,
    pub owner: String,
    pub offload_compatible: bool,
    pub state: WorkloadState,
    pub submitted_at: Time,
    pub admitted_at: Option<Time>,
    pub finished_at: Option<Time>,
    /// Which node admitted it (for the Fig. 2 series), virtual or
    /// physical — an interned handle; resolve via `Cluster::name_of`.
    pub assigned_node: Option<NodeId>,
    pub requeues: u32,
    /// Why this workload was last evicted, if ever — distinguishes the
    /// §4 notebook-contention path from cohort quota reclaim (and both
    /// from injected faults).
    pub preempted_by: Option<PreemptReason>,
    /// The [`PreemptReason::FaultEviction`] subset of `requeues`:
    /// how many times injected faults have displaced this workload.
    /// Drives the bounded retry budget — see [`Kueue::requeue_faulted`].
    pub fault_requeues: u32,
    /// Admission backoff deadline after a fault requeue: the workload
    /// is skipped by admission cycles strictly before this instant.
    /// The raw deadline takes effect at the first admission-grid
    /// instant at or after it, identically in both loop modes (the
    /// `chaos` module's backoff-on-grid rule).
    pub not_before: Option<Time>,
    /// When a fault last evicted this workload — cleared on
    /// re-admission, feeding the recovery-time stats.
    pub fault_evicted_at: Option<Time>,
    /// Zone-scoping memory: the admission epoch whose local placement
    /// search exhaustively refused this workload (no feasible node in
    /// any shard — the searched shards said no and any pruned shard
    /// was provably still-no). `None` = never refused, or requeued
    /// since. A scoped cycle re-searches only shards with a capacity
    /// edge after this epoch; see [`Kueue::note_capacity_edges`].
    pub refused_epoch: Option<u64>,
}

/// A ClusterQueue: a leaf of the quota tree. Nominal quota is a
/// [`QuotaVec`] over the *local* farm (`None` = opportunistic, bounded
/// only by actual free capacity); membership in a [`Cohort`] makes the
/// idle part of the nominal quota borrowable by cohort peers, within
/// the borrowing/lending limits.
#[derive(Clone, Debug)]
pub struct ClusterQueue {
    pub name: String,
    /// Max local usage admitted concurrently without borrowing
    /// (None = opportunistic; takes no part in cohort math).
    pub nominal: Option<QuotaVec>,
    /// Cohort this queue lends to / borrows from, if any.
    pub cohort: Option<String>,
    /// Cap on usage above nominal (None = bounded only by the cohort's
    /// lendable headroom). Meaningless without a cohort.
    pub borrowing_limit: Option<QuotaVec>,
    /// Cap on how much idle nominal quota cohort peers may borrow
    /// (None = all of it).
    pub lending_limit: Option<QuotaVec>,
    /// Admitted local usage.
    pub used: QuotaVec,
}

impl ClusterQueue {
    pub fn opportunistic(name: &str) -> Self {
        ClusterQueue {
            name: name.to_string(),
            nominal: None,
            cohort: None,
            borrowing_limit: None,
            lending_limit: None,
            used: QuotaVec::ZERO,
        }
    }

    pub fn with_nominal(name: &str, nominal: QuotaVec) -> Self {
        ClusterQueue {
            nominal: Some(nominal),
            ..Self::opportunistic(name)
        }
    }

    /// Builder: join a cohort (created on first reference).
    pub fn in_cohort(mut self, cohort: &str) -> Self {
        self.cohort = Some(cohort.to_string());
        self
    }

    /// Builder: cap usage above nominal.
    pub fn borrowing(mut self, limit: QuotaVec) -> Self {
        self.borrowing_limit = Some(limit);
        self
    }

    /// Builder: cap how much idle nominal quota peers may borrow.
    pub fn lending(mut self, limit: QuotaVec) -> Self {
        self.lending_limit = Some(limit);
        self
    }

    /// Usage above nominal (zero for opportunistic queues).
    pub fn borrowed(&self) -> QuotaVec {
        match self.nominal {
            Some(n) => self.used.saturating_sub(n),
            None => QuotaVec::ZERO,
        }
    }

    /// Idle nominal quota available to cohort peers.
    pub fn lendable(&self) -> QuotaVec {
        match self.nominal {
            Some(n) => borrow_lend(self.used, n, self.lending_limit).1,
            None => QuotaVec::ZERO,
        }
    }
}

/// `(borrowed, lendable)` of a queue at hypothetical usage `used`.
fn borrow_lend(
    used: QuotaVec,
    nominal: QuotaVec,
    lending_limit: Option<QuotaVec>,
) -> (QuotaVec, QuotaVec) {
    let borrowed = used.saturating_sub(nominal);
    let idle = nominal.saturating_sub(used);
    let lendable = match lending_limit {
        Some(l) => idle.min(l),
        None => idle,
    };
    (borrowed, lendable)
}

/// What the quota tree says about admitting a request into a queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum QuotaDecision {
    /// Within nominal quota and cohort-feasible.
    AdmitNominal,
    /// Above nominal but within the borrowing limit and the cohort's
    /// lendable headroom.
    AdmitBorrow,
    /// Within nominal quota, but the cohort is exhausted by borrowers:
    /// the queue is entitled to reclaim.
    ReclaimEntitled,
    /// Over quota with no path to admission this cycle.
    Blocked,
}

/// How an admission consumed quota (drives the stat counters).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum AdmitVia {
    Nominal,
    Borrow,
    Reclaim,
}

/// A junior-first reclaim victim candidate.
struct ReclaimCandidate {
    wid: WorkloadId,
    pod: PodId,
    queue: String,
    r: QuotaVec,
    admitted_at: Time,
}

/// The controller.
#[derive(Debug, Default)]
pub struct Kueue {
    queues: BTreeMap<String, ClusterQueue>,
    /// The cohort layer of the quota tree, keyed by cohort name.
    /// Created implicitly on first queue reference.
    cohorts: BTreeMap<String, Cohort>,
    workloads: BTreeMap<WorkloadId, Workload>,
    pending: VecDeque<WorkloadId>,
    /// Reverse map: which workload owns a pod. Maintained by submit and
    /// respawn so the coordinator's reconcile path resolves a finished
    /// pod in O(log n) instead of scanning every workload.
    pod_owner: BTreeMap<PodId, WorkloadId>,
    next_id: u64,
    /// Round-robin cursor over virtual nodes.
    vnode_rr: usize,
    /// Admission stats for the experiments.
    pub n_admitted_local: u64,
    pub n_admitted_virtual: u64,
    /// Local admissions that went above nominal quota (pipeline stage 4).
    pub n_admitted_borrow: u64,
    /// Local admissions that required evicting borrowers (stage 5).
    pub n_admitted_reclaim: u64,
    /// Evictions, any reason (notebook contention + reclaim).
    pub n_evictions: u64,
    /// The [`PreemptReason::ReclaimBorrowed`] subset of `n_evictions`.
    pub n_reclaim_evictions: u64,
    /// Workloads requeued because an injected fault evicted their pod
    /// (the `chaos` recovery path; disjoint from `n_evictions`).
    pub n_fault_evictions: u64,
    /// Fault-requeued workloads that ran out of retry budget and went
    /// terminal-Failed instead of requeueing.
    pub n_retry_exhausted: u64,
    /// Fault-recovery latency (fault eviction → re-admission): count,
    /// running sum and max, for the monitoring scrape.
    pub n_fault_recoveries: u64,
    pub fault_recovery_sum_s: f64,
    pub fault_recovery_max_s: f64,
    /// Edge signal for the reactive coordinator: set on every
    /// pending-set or quota delta (submit, requeue, respawn, finish,
    /// reclaim eviction) — exactly the transitions after which an
    /// admission cycle could do new work. Consumed by
    /// [`Kueue::take_dirty`].
    dirty: bool,
    /// Zone-scoped admission (PR-9). `false` — the default, and what
    /// every Polling platform keeps — makes every placement search
    /// level-triggered over all shards: the oracle. The reactive
    /// platform sets it `true`, and cycles then prune, for each
    /// previously-refused workload, every shard with no capacity edge
    /// since that refusal. Pruning is *exact*: binds only consume
    /// capacity, every capacity-freeing path raises a shard-hinted
    /// edge ([`Cluster::take_dirty_shards`]) and scheduler uncordons
    /// re-open every shard, so a pruned shard provably still refuses —
    /// which is why decisions stay byte-identical to the polling
    /// oracle across the whole mode matrix.
    pub shard_scoped: bool,
    /// Monotonic non-idle-cycle counter: the grid `refused_epoch` and
    /// `shard_edge_epoch` are measured on.
    admission_epoch: u64,
    /// Per shard: the earliest epoch whose cycles must re-search it
    /// (`admission_epoch + 1` at note time). A workload refused at
    /// epoch `e` re-searches shard `s` iff `shard_edge_epoch[s] > e`.
    shard_edge_epoch: Vec<u64>,
    /// Per shard: non-idle cycles that searched it (monitoring).
    shard_visits: Vec<u64>,
    /// Per shard: non-idle cycles that pruned it entirely (monitoring).
    shard_skips: Vec<u64>,
}

impl Kueue {
    pub fn new() -> Self {
        let mut k = Kueue::default();
        // The platform's default queue is opportunistic local batch.
        k.add_queue(ClusterQueue::opportunistic("local-batch"));
        k
    }

    /// Register a queue, creating its cohort on first reference.
    pub fn add_queue(&mut self, q: ClusterQueue) {
        if let Some(c) = &q.cohort {
            self.cohorts
                .entry(c.clone())
                .or_insert_with(|| Cohort::new(c))
                .add_member(&q.name);
        }
        self.queues.insert(q.name.clone(), q);
    }

    pub fn queue(&self, name: &str) -> Option<&ClusterQueue> {
        self.queues.get(name)
    }

    pub fn cohort(&self, name: &str) -> Option<&Cohort> {
        self.cohorts.get(name)
    }

    pub fn cohorts(&self) -> impl Iterator<Item = &Cohort> {
        self.cohorts.values()
    }

    /// Point-in-time aggregate over one cohort (the pipeline's
    /// snapshot stage; also exported to the monitoring scrape).
    pub fn cohort_usage(&self, name: &str) -> CohortUsage {
        let mut u = CohortUsage::default();
        if let Some(c) = self.cohorts.get(name) {
            for m in c.members() {
                if let Some(q) = self.queues.get(m) {
                    if let Some(n) = q.nominal {
                        u.capacity = u.capacity.add(n);
                        u.used = u.used.add(q.used);
                        u.borrowed = u.borrowed.add(q.borrowed());
                        u.lendable = u.lendable.add(q.lendable());
                    }
                }
            }
        }
        u
    }

    /// Re-derive the quota-tree invariants from scratch. Used by the
    /// property harness (`rust/tests/quota_prop.rs`) after arbitrary
    /// admission/eviction interleavings.
    pub fn check_cohort_invariants(&self) -> Result<(), String> {
        for (name, q) in &self.queues {
            if let Some(n) = q.nominal {
                let ceiling = match (&q.cohort, q.borrowing_limit) {
                    // No cohort → nothing to borrow from.
                    (None, _) => n,
                    (Some(_), Some(bl)) => n.add(bl),
                    (Some(_), None) => QuotaVec::MAX,
                };
                if !q.used.fits_within(ceiling) {
                    return Err(format!(
                        "queue {name}: used {:?} exceeds ceiling {:?}",
                        q.used, ceiling
                    ));
                }
            }
        }
        for name in self.cohorts.keys() {
            let u = self.cohort_usage(name);
            if !u.borrowed.fits_within(u.lendable) {
                return Err(format!(
                    "cohort {name}: borrowed {:?} exceeds lendable {:?}",
                    u.borrowed, u.lendable
                ));
            }
            if !u.used.fits_within(u.capacity) {
                return Err(format!(
                    "cohort {name}: used {:?} exceeds capacity {:?}",
                    u.used, u.capacity
                ));
            }
        }
        Ok(())
    }

    /// Enqueue a workload for an already-created (Pending) pod.
    pub fn submit(
        &mut self,
        pod: PodId,
        queue: &str,
        owner: &str,
        offload_compatible: bool,
        now: Time,
    ) -> Result<WorkloadId, String> {
        if !self.queues.contains_key(queue) {
            return Err(format!("no such queue {queue}"));
        }
        self.next_id += 1;
        let id = WorkloadId(self.next_id);
        self.workloads.insert(
            id,
            Workload {
                id,
                pod,
                queue: queue.to_string(),
                owner: owner.to_string(),
                offload_compatible,
                state: WorkloadState::Queued,
                submitted_at: now,
                admitted_at: None,
                finished_at: None,
                assigned_node: None,
                requeues: 0,
                preempted_by: None,
                fault_requeues: 0,
                not_before: None,
                fault_evicted_at: None,
                refused_epoch: None,
            },
        );
        self.pod_owner.insert(pod, id);
        self.pending.push_back(id);
        self.dirty = true;
        Ok(id)
    }

    /// Consume the pending-set/quota edge signal (see the `dirty`
    /// field). The reactive coordinator calls this after every event to
    /// decide whether an admission cycle is worth scheduling.
    pub fn take_dirty(&mut self) -> bool {
        std::mem::take(&mut self.dirty)
    }

    /// Record capacity edges for `shards`: cycles from the next epoch
    /// on re-search them for every previously-refused workload. The
    /// reactive coordinator feeds [`Cluster::take_dirty_shards`] here
    /// after every event; shards beyond the known range grow the
    /// bookkeeping (they are new, so nothing was refused on them yet).
    pub fn note_capacity_edges(&mut self, shards: &ShardSet) {
        let next = self.admission_epoch + 1;
        for s in shards.iter() {
            if s >= self.shard_edge_epoch.len() {
                self.shard_edge_epoch.resize(s + 1, next);
                self.shard_visits.resize(s + 1, 0);
                self.shard_skips.resize(s + 1, 0);
            }
            self.shard_edge_epoch[s] = next;
        }
    }

    /// Record a capacity edge with no shard locality (scheduler
    /// uncordon, level-triggered sweeps): every shard is re-searched
    /// from the next epoch on.
    pub fn note_capacity_edge_all(&mut self) {
        let next = self.admission_epoch + 1;
        for e in self.shard_edge_epoch.iter_mut() {
            *e = next;
        }
    }

    /// Per-shard count of non-idle admission cycles that searched the
    /// shard for at least one workload (sized at the first non-idle
    /// cycle; reset by a reshard).
    pub fn shard_visits(&self) -> &[u64] {
        &self.shard_visits
    }

    /// Per-shard count of non-idle admission cycles that pruned the
    /// shard entirely (complement of [`Kueue::shard_visits`] over
    /// non-idle cycles).
    pub fn shard_skips(&self) -> &[u64] {
        &self.shard_skips
    }

    pub fn workload(&self, id: WorkloadId) -> Option<&Workload> {
        self.workloads.get(&id)
    }

    pub fn workloads(&self) -> impl Iterator<Item = &Workload> {
        self.workloads.values()
    }

    /// The workload owning `pod` (its current incarnation), if any.
    pub fn workload_of_pod(&self, pod: PodId) -> Option<WorkloadId> {
        self.pod_owner.get(&pod).copied()
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    /// Pending workload ids in queue order (front first) — exposed for
    /// the seniority invariant tests.
    pub fn pending_ids(&self) -> Vec<WorkloadId> {
        self.pending.iter().copied().collect()
    }

    /// Earliest strictly-future fault-backoff deadline among pending
    /// workloads. The coordinator re-arms the reactive admission timer
    /// here after a cycle that skipped backing-off workloads — nothing
    /// else re-raises the dirty edge while everyone waits.
    pub fn next_not_before(&self, now: Time) -> Option<Time> {
        self.pending
            .iter()
            .filter_map(|id| self.workloads[id].not_before)
            .filter(|&t| t > now)
            .fold(None, |m: Option<Time>, t| {
                Some(m.map_or(t, |x| x.min(t)))
            })
    }

    /// What the quota tree says about admitting `r` into `queue`,
    /// against live usage. Component-wise over [`QuotaVec`] dims.
    fn quota_decision(&self, queue: &str, r: QuotaVec) -> QuotaDecision {
        let q = &self.queues[queue];
        let nominal = match q.nominal {
            None => return QuotaDecision::AdmitNominal, // opportunistic
            Some(n) => n,
        };
        let used2 = q.used.add(r);
        let within = used2.fits_within(nominal);
        let cohort = match &q.cohort {
            None => {
                return if within {
                    QuotaDecision::AdmitNominal
                } else {
                    QuotaDecision::Blocked
                }
            }
            Some(c) => c,
        };
        // Re-derive the cohort invariant with this queue's usage
        // advanced to `used2` — admission is legal only if the
        // post-admission state still satisfies borrowed ≤ lendable.
        let agg = self.cohort_usage(cohort);
        let (b2, l2) = borrow_lend(used2, nominal, q.lending_limit);
        let borrowed_after = agg.borrowed.saturating_sub(q.borrowed()).add(b2);
        let lendable_after = agg.lendable.saturating_sub(q.lendable()).add(l2);
        let feasible = borrowed_after.fits_within(lendable_after);
        if within {
            if feasible {
                QuotaDecision::AdmitNominal
            } else {
                QuotaDecision::ReclaimEntitled
            }
        } else {
            let cap_ok = match q.borrowing_limit {
                None => true,
                Some(bl) => used2.fits_within(nominal.add(bl)),
            };
            if cap_ok && feasible {
                QuotaDecision::AdmitBorrow
            } else {
                QuotaDecision::Blocked
            }
        }
    }

    /// Dominant-resource fair share of a queue: usage against the
    /// cohort capacity (cohort members) or its own nominal quota
    /// (standalone queues); opportunistic queues pin to zero so a
    /// single-queue platform keeps the seed's pure-FIFO order.
    fn queue_share(&self, q: &ClusterQueue) -> Share {
        match (&q.cohort, q.nominal) {
            (Some(c), Some(_)) => {
                q.used.dominant_share(self.cohort_usage(c).capacity)
            }
            (None, Some(n)) => q.used.dominant_share(n),
            _ => Share::ZERO,
        }
    }

    /// Round-robin over virtual nodes that admit and fit the pod.
    ///
    /// Candidates are put in node-NAME order in both modes: the linear
    /// scan iterates the cluster's name-ordered node walk, while the
    /// indexed set — concatenated across the per-shard indexes in no
    /// particular order — is re-sorted through the interner's name
    /// table. The round-robin cursor therefore lands on the same site
    /// either way — event ordering is mode-independent, shard-count-
    /// independent, and byte-compatible with the string-keyed core.
    fn pick_virtual_node(
        &mut self,
        cluster: &Cluster,
        scheduler: &Scheduler,
        pod: PodId,
    ) -> Option<NodeId> {
        let admits = |n: &crate::cluster::Node| {
            !scheduler.cordoned.contains(n.name.as_str())
                && cluster
                    .pod(pod)
                    .map(|p| {
                        p.spec.tolerates(&n.taints)
                            && n.can_fit(&p.spec.resources)
                            && p.spec
                                .node_selector
                                .as_deref()
                                .map_or(true, |s| s == n.name)
                    })
                    .unwrap_or(false)
        };
        let candidates: Vec<NodeId> = match scheduler.mode {
            // The seed's scan: every node, filtered down to virtuals.
            PlacementMode::LinearScan => cluster
                .nodes_with_ids()
                .filter(|&(_, n)| n.virtual_node && admits(n))
                .map(|(id, _)| id)
                .collect(),
            // Indexed: only the (few) registered virtual nodes,
            // gathered across every shard's index.
            PlacementMode::Indexed => {
                let mut v: Vec<NodeId> = cluster
                    .virtual_node_ids()
                    .into_iter()
                    .filter(|&id| {
                        cluster.node_by_id(id).map_or(false, |n| admits(n))
                    })
                    .collect();
                v.sort_by(|&a, &b| cluster.name_of(a).cmp(cluster.name_of(b)));
                v
            }
        };
        if candidates.is_empty() {
            return None;
        }
        let pick = candidates[self.vnode_rr % candidates.len()];
        self.vnode_rr += 1;
        Some(pick)
    }

    /// Post-placement bookkeeping shared by the three admitting stages.
    fn record_admission(
        &mut self,
        cluster: &Cluster,
        id: WorkloadId,
        node: NodeId,
        r: QuotaVec,
        now: Time,
        via: AdmitVia,
    ) {
        let is_virtual = cluster
            .node_by_id(node)
            .map(|n| n.virtual_node)
            .unwrap_or(false);
        if is_virtual {
            self.n_admitted_virtual += 1;
        } else {
            self.n_admitted_local += 1;
            match via {
                AdmitVia::Nominal => {}
                AdmitVia::Borrow => self.n_admitted_borrow += 1,
                AdmitVia::Reclaim => self.n_admitted_reclaim += 1,
            }
            // Only local admissions consume quota. No `queue.clone()`
            // here: the queue map is indexed through a fresh
            // `&self.workloads[&id].queue` borrow instead (hot path).
            let q = self.queues.get_mut(&self.workloads[&id].queue).unwrap();
            q.used = q.used.add(r);
        }
        let w = self.workloads.get_mut(&id).unwrap();
        w.state = WorkloadState::Admitted;
        w.admitted_at = Some(now);
        w.assigned_node = Some(node);
        w.not_before = None;
        w.refused_epoch = None;
        if let Some(t0) = w.fault_evicted_at.take() {
            let lag = (now - t0).max(0.0);
            self.n_fault_recoveries += 1;
            self.fault_recovery_sum_s += lag;
            self.fault_recovery_max_s = self.fault_recovery_max_s.max(lag);
        }
    }

    /// One admission cycle: the five-stage pipeline described in the
    /// module docs (snapshot → order → nominal → borrow → reclaim).
    /// Returns workloads admitted this cycle, in admission order.
    pub fn admission_cycle(
        &mut self,
        cluster: &mut Cluster,
        scheduler: &Scheduler,
        now: Time,
    ) -> Vec<WorkloadId> {
        if self.pending.is_empty() {
            // Keep the seed's O(1) idle cycle: the polling oracle runs
            // this every period whether or not there is work.
            return Vec::new();
        }
        // Zone scoping: one epoch per non-idle cycle. A reshard (or
        // the first sight of this cluster) changes shard identity, so
        // the per-shard memory is meaningless — re-open everything
        // and forget refusals.
        self.admission_epoch += 1;
        let n_shards = cluster.n_shards();
        if self.shard_edge_epoch.len() != n_shards {
            self.shard_edge_epoch = vec![self.admission_epoch; n_shards];
            self.shard_visits = vec![0; n_shards];
            self.shard_skips = vec![0; n_shards];
            for w in self.workloads.values_mut() {
                w.refused_epoch = None;
            }
        }
        let scoped = self.shard_scoped
            && scheduler.mode == PlacementMode::Indexed
            && n_shards > 0;
        // Shards this cycle actually searched (for the monitoring
        // gauges): per-workload scoped sets accumulate here; any
        // unscoped search — a never-refused workload, the LinearScan
        // oracle, the offload and reclaim paths — visits every shard.
        let mut visited = ShardSet::new();
        let mut full_visit = false;
        // Stage 1 — snapshot: per-queue shares and starved cohorts.
        // A cohort is starved while some pending workload's queue is
        // nominally entitled to it; stage 4 refuses to lend into a
        // starved cohort so a borrower never leapfrogs the owner the
        // reclaim stage is about to serve. Cohortless setups skip the
        // scan (nothing can starve without borrowers).
        // Fault-backoff eligibility: a workload requeued by the chaos
        // path waits out its `not_before` deadline. It stays pending
        // (seniority intact) but takes no part in this cycle — not
        // even the starved snapshot, so a backing-off owner does not
        // freeze its cohort against borrowers it cannot outbid yet.
        let backoff_ok =
            |w: &Workload| w.not_before.map_or(true, |t| t <= now);
        let mut starved: BTreeSet<String> = BTreeSet::new();
        if !self.cohorts.is_empty() {
            for &id in &self.pending {
                let w = &self.workloads[&id];
                if !backoff_ok(w) {
                    continue;
                }
                let q = &self.queues[&w.queue];
                if let (Some(n), Some(c)) = (q.nominal, &q.cohort) {
                    if let Some(p) = cluster.pod(w.pod) {
                        if p.phase == PodPhase::Pending
                            && q.used
                                .add(QuotaVec::of(&p.spec.resources))
                                .fits_within(n)
                        {
                            starved.insert(c.clone());
                        }
                    }
                }
            }
        }

        // Stage 2 — order: by queue share (exact rationals), FIFO
        // within equal shares (stable sort, shares resolved once per
        // workload — not per comparison). A single-queue platform is
        // the seed's pure FIFO and skips the sort entirely.
        let order: Vec<WorkloadId> = if self.queues.len() > 1 {
            let shares: BTreeMap<&str, Share> = self
                .queues
                .iter()
                .map(|(name, q)| (name.as_str(), self.queue_share(q)))
                .collect();
            let mut keyed: Vec<(Share, WorkloadId)> = self
                .pending
                .iter()
                .filter(|id| backoff_ok(&self.workloads[id]))
                .map(|&id| {
                    (shares[self.workloads[&id].queue.as_str()], id)
                })
                .collect();
            keyed.sort_by(|a, b| a.0.cmp(&b.0));
            keyed.into_iter().map(|(_, id)| id).collect()
        } else {
            self.pending
                .iter()
                .copied()
                .filter(|id| backoff_ok(&self.workloads[id]))
                .collect()
        };

        let mut admitted = Vec::new();
        let mut done: BTreeSet<WorkloadId> = BTreeSet::new();

        // Stage 3 — admit within nominal: local first (opportunistic
        // use of the farm; batch spreads to minimise the eviction
        // blast radius), then virtual nodes round-robin across sites
        // with room. A reclaim-entitled workload may still offload —
        // remote capacity consumes no cohort quota.
        for &id in &order {
            let (pod_id, offloadable) = {
                let w = &self.workloads[&id];
                (w.pod, w.offload_compatible)
            };
            let r = match cluster.pod(pod_id) {
                Some(p) if p.phase == PodPhase::Pending => {
                    QuotaVec::of(&p.spec.resources)
                }
                _ => {
                    // Pod vanished or already handled; drop the workload.
                    self.workloads.get_mut(&id).unwrap().state =
                        WorkloadState::Failed;
                    done.insert(id);
                    continue;
                }
            };
            let decision =
                self.quota_decision(self.workloads[&id].queue.as_str(), r);
            let mut placed: Option<NodeId> = None;
            if decision == QuotaDecision::AdmitNominal {
                // The unclassified try_place keeps a failed attempt
                // cheap under the index (the workload just stays
                // queued); under zone scoping a previously-refused
                // workload prunes down to the shards with a capacity
                // edge since — exact, so decisions do not change.
                if let Some(node) = self.place_local(
                    cluster,
                    scheduler,
                    id,
                    pod_id,
                    scoped,
                    &mut visited,
                    &mut full_visit,
                ) {
                    if cluster.bind_to(pod_id, node).is_ok() {
                        placed = Some(node);
                    }
                }
            }
            if placed.is_none()
                && offloadable
                && matches!(
                    decision,
                    QuotaDecision::AdmitNominal | QuotaDecision::ReclaimEntitled
                )
            {
                if let Some(node) =
                    self.pick_virtual_node(cluster, scheduler, pod_id)
                {
                    if cluster.bind_to(pod_id, node).is_ok() {
                        placed = Some(node);
                    }
                }
            }
            if let Some(node) = placed {
                self.record_admission(
                    cluster,
                    id,
                    node,
                    r,
                    now,
                    AdmitVia::Nominal,
                );
                admitted.push(id);
                done.insert(id);
            }
        }

        // Stages 4 and 5 exist only where cohorts do — without them
        // nothing can borrow and nothing can reclaim, so cohortless
        // setups (every pre-PR-4 scenario) keep the seed's single
        // pending pass.
        let mut reclaimed_any = false;
        if self.cohorts.is_empty() {
            self.pending.retain(|id| !done.contains(id));
            if !admitted.is_empty() && !self.pending.is_empty() {
                self.dirty = true;
            }
            self.tally_shard_scan(n_shards, &visited, full_visit);
            return admitted;
        }

        // Stage 4 — admit by borrowing idle cohort headroom. Local
        // only, deliberately: a workload *within* nominal already got
        // its virtual-node attempt in stage 3, while an above-nominal
        // workload gets neither local-borrow-free placement nor
        // offload — the nominal quota throttles a tenant's total
        // activity exactly as the seed's flat `has_room` gate did
        // (remote capacity is not a way around your share; only the
        // cohort's idle headroom is).
        for &id in &order {
            if done.contains(&id) {
                continue;
            }
            let pod_id = self.workloads[&id].pod;
            let r = match cluster.pod(pod_id) {
                Some(p) if p.phase == PodPhase::Pending => {
                    QuotaVec::of(&p.spec.resources)
                }
                _ => continue,
            };
            match self.queues[&self.workloads[&id].queue].cohort.as_deref() {
                Some(c) if !starved.contains(c) => {}
                _ => continue, // no cohort, or a starving owner goes first
            }
            if self.quota_decision(self.workloads[&id].queue.as_str(), r)
                != QuotaDecision::AdmitBorrow
            {
                continue;
            }
            if let Some(node) = self.place_local(
                cluster,
                scheduler,
                id,
                pod_id,
                scoped,
                &mut visited,
                &mut full_visit,
            ) {
                if cluster.bind_to(pod_id, node).is_ok() {
                    self.record_admission(
                        cluster,
                        id,
                        node,
                        r,
                        now,
                        AdmitVia::Borrow,
                    );
                    admitted.push(id);
                    done.insert(id);
                }
            }
        }

        // Stage 5 — plan reclaim (see the module docs). The junior-
        // first candidate list is computed once per (cohort, cycle)
        // and maintained incrementally across evictions: within a
        // cycle the only mutations that can touch it are the reclaim
        // evictions themselves (stage-5 admissions are within-nominal,
        // so they never mint new borrowers), so removing each evicted
        // candidate keeps the cache equal to a recompute up to the
        // per-queue borrowed-amount caps — which were snapshotted at
        // their cycle-start maximum and only shrink, so the cache can
        // only over-expose junior candidates that the live
        // `quota_reclaim_victims` no-progress guard then spares. A
        // reclaim *wave* (many starving workloads, one cohort) thus
        // pays one O(W log W) scan per cycle instead of one per
        // workload (the `cohort_churn` bench scenario).
        let mut cand_cache: BTreeMap<String, Vec<ReclaimCandidate>> =
            BTreeMap::new();
        for &id in &order {
            if done.contains(&id) {
                continue;
            }
            let pod_id = self.workloads[&id].pod;
            let r = match cluster.pod(pod_id) {
                Some(p) if p.phase == PodPhase::Pending => {
                    QuotaVec::of(&p.spec.resources)
                }
                _ => continue,
            };
            let (cohort, nominal) = {
                let q = &self.queues[&self.workloads[&id].queue];
                match (&q.cohort, q.nominal) {
                    (Some(c), Some(n)) => (c.clone(), n),
                    _ => continue, // cohortless queues never reclaim
                }
            };
            // Only a queue within its nominal entitlement reclaims.
            if !self.queues[&self.workloads[&id].queue]
                .used
                .add(r)
                .fits_within(nominal)
            {
                continue;
            }
            let queue_name = self.workloads[&id].queue.clone();
            if !cand_cache.contains_key(&cohort) {
                let list = self.reclaim_candidates(cluster, &cohort);
                cand_cache.insert(cohort.clone(), list);
            }
            let cands = cand_cache.get_mut(&cohort).unwrap();
            // Prune the cache in place against LIVE borrow state
            // (`live_eligible`): per-queue borrowed amounts only
            // shrink within a cycle, so ineligibility is monotone and
            // the cheap O(cands) trim — not a full rebuild — restores
            // exact recompute semantics for EVERY consumer below (the
            // quota stage included: evicting a no-longer-borrowing
            // queue's workload would still "shrink the deficit" by
            // growing that queue's lendable headroom, so the
            // no-progress guard alone cannot spare stale candidates).
            {
                let keep: BTreeSet<PodId> =
                    self.live_eligible(&cands[..]).into_iter().collect();
                cands.retain(|c| keep.contains(&c.pod));
            }
            // The reclaim path searches (and plans) over the whole
            // farm — eviction changes capacity mid-cycle, so pruning
            // does not apply here.
            full_visit = true;
            // Physical-reachability guard: never evict for a pod that
            // cannot be placed even after evicting every remaining
            // candidate (a non-quota dimension like memory, or a
            // selector onto a borrower-free node, can make it
            // unsatisfiable). Eviction only frees resources, so a plan
            // found here stays achievable after the quota-stage prefix
            // executes.
            if scheduler
                .try_place(cluster, pod_id, ScoringPolicy::Spread, false)
                .is_none()
            {
                let pods: Vec<PodId> = cands.iter().map(|c| c.pod).collect();
                if scheduler.plan_reclaim(cluster, pod_id, &pods).is_none() {
                    continue;
                }
            }
            // Quota stage: the junior-first victims (each repaying a
            // blocked dimension) that make this admission
            // cohort-feasible — or nothing at all if even evicting
            // every eligible borrower would not (no wasted evictions,
            // no requeue/re-borrow livelock).
            let victims = match self
                .quota_reclaim_victims(&cohort, &queue_name, r, &cands[..])
            {
                Some(v) => v,
                None => continue,
            };
            let mut vit = victims.into_iter().peekable();
            let mut keep = Vec::with_capacity(cands.len());
            let mut evict: Vec<(WorkloadId, PodId)> = Vec::new();
            for (k, c) in std::mem::take(cands).into_iter().enumerate() {
                if vit.peek() == Some(&k) {
                    vit.next();
                    evict.push((c.wid, c.pod));
                } else {
                    keep.push(c);
                }
            }
            *cands = keep;
            for (wid, pod) in evict {
                self.reclaim_evict(cluster, wid, pod);
                reclaimed_any = true;
            }
            // Physical stage: place into the freed space, else plan a
            // targeted single-node eviction over the remaining
            // junior-first victims (also removed from the cycle cache).
            // Re-trimmed once more: the quota-stage evictions above
            // changed borrow state again, and the planner has no quota
            // guard of its own — handing it a stale candidate whose
            // queue stopped borrowing would evict a within-nominal
            // workload the per-workload recompute could never touch.
            let mut placed: Option<NodeId> = None;
            if let Some(node) =
                scheduler.try_place(cluster, pod_id, ScoringPolicy::Spread, false)
            {
                placed = Some(node);
            } else {
                let pods = self.live_eligible(&cands[..]);
                if let Some((node, victims)) =
                    scheduler.plan_reclaim(cluster, pod_id, &pods)
                {
                    for &v in &victims {
                        if let Some(c) = cands.iter().find(|c| c.pod == v) {
                            let (wid, pod) = (c.wid, c.pod);
                            self.reclaim_evict(cluster, wid, pod);
                            reclaimed_any = true;
                        }
                    }
                    cands.retain(|c| !victims.contains(&c.pod));
                    placed = Some(node);
                }
            }
            if let Some(node) = placed {
                if cluster.bind_to(pod_id, node).is_ok() {
                    self.record_admission(
                        cluster,
                        id,
                        node,
                        r,
                        now,
                        AdmitVia::Reclaim,
                    );
                    admitted.push(id);
                    done.insert(id);
                }
            }
        }

        self.tally_shard_scan(n_shards, &visited, full_visit);
        self.pending.retain(|id| !done.contains(id));
        if reclaimed_any {
            // Reclaim kills the victims' pods like notebook preemption
            // does; resubmit fresh pods so the next cycle can retry
            // them (raises the dirty edge for the reactive cascade).
            self.respawn_evicted_pods(cluster);
        }
        if !admitted.is_empty() && !self.pending.is_empty() {
            // An admission is itself a quota/pending delta: serving a
            // starving owner un-freezes its cohort for borrowers this
            // cycle already passed over (the starved set is a stage-1
            // snapshot). Polling naturally retries next period; raise
            // the edge so the reactive loop retries on the same grid
            // instant and decisions stay byte-identical across modes.
            // The cascade terminates: a cycle that admits nothing
            // raises no edge.
            self.dirty = true;
        }
        admitted
    }

    /// Stage-3/4 local placement with zone scoping. When scoping is
    /// active and the workload carries a refusal from epoch `e`, only
    /// shards with a capacity edge after `e` are searched — and if no
    /// shard has one, the search is skipped outright. Exact in both
    /// cases: the refusal at `e` was exhaustive, and a shard without a
    /// freeing edge since can only have *lost* capacity (binds,
    /// cordons), so it provably still refuses. Otherwise the full
    /// mode-parity search runs. The refusal memory is (re)stamped with
    /// the current epoch on refusal and cleared on success; the
    /// cycle's visited set feeds the per-shard monitoring gauges.
    #[allow(clippy::too_many_arguments)]
    fn place_local(
        &mut self,
        cluster: &Cluster,
        scheduler: &Scheduler,
        id: WorkloadId,
        pod_id: PodId,
        scoped: bool,
        visited: &mut ShardSet,
        full_visit: &mut bool,
    ) -> Option<NodeId> {
        let node = match self.workloads[&id].refused_epoch.filter(|_| scoped)
        {
            Some(e) => {
                let mut allowed = ShardSet::new();
                for (s, &edge) in self.shard_edge_epoch.iter().enumerate() {
                    if edge > e {
                        allowed.insert(s);
                        visited.insert(s);
                    }
                }
                if allowed.is_empty() {
                    // Every shard already refused this workload and
                    // none has freed capacity since: still infeasible.
                    None
                } else {
                    scheduler.try_place_scoped(
                        cluster,
                        pod_id,
                        ScoringPolicy::Spread,
                        false,
                        Some(&allowed),
                    )
                }
            }
            None => {
                *full_visit = true;
                scheduler.try_place(
                    cluster,
                    pod_id,
                    ScoringPolicy::Spread,
                    false,
                )
            }
        };
        let w = self.workloads.get_mut(&id).unwrap();
        w.refused_epoch = if node.is_none() {
            Some(self.admission_epoch)
        } else {
            None
        };
        node
    }

    /// Fold one non-idle cycle's search scope into the per-shard
    /// visit/skip counters (the `export_loop_shards` gauges). Idle
    /// cycles count nothing in either mode, so a polling platform's
    /// visit counts measure *busy* cycles — the number a zone-scoped
    /// reactive run strictly undercuts on zone-skewed churn.
    fn tally_shard_scan(
        &mut self,
        n_shards: usize,
        visited: &ShardSet,
        full_visit: bool,
    ) {
        for s in 0..n_shards {
            if full_visit || visited.contains(s) {
                self.shard_visits[s] += 1;
            } else {
                self.shard_skips[s] += 1;
            }
        }
    }

    /// Admitted local workloads of this cohort's borrowing queues,
    /// most-junior first (latest admission, then youngest id), capped
    /// per queue at its currently-borrowed amount so eviction planning
    /// stops once a lender stops borrowing.
    fn reclaim_candidates(
        &self,
        cluster: &Cluster,
        cohort: &str,
    ) -> Vec<ReclaimCandidate> {
        let cohort = match self.cohorts.get(cohort) {
            Some(c) => c,
            None => return Vec::new(),
        };
        let mut v: Vec<ReclaimCandidate> = Vec::new();
        for w in self.workloads.values() {
            if w.state != WorkloadState::Admitted || !cohort.contains(&w.queue)
            {
                continue;
            }
            let node = match w.assigned_node {
                Some(n) => n,
                None => continue,
            };
            // Only local usage holds cohort quota.
            if cluster.node_by_id(node).map_or(true, |n| n.virtual_node) {
                continue;
            }
            let p = match cluster.pod(w.pod) {
                Some(p) if p.phase == PodPhase::Running => p,
                _ => continue,
            };
            v.push(ReclaimCandidate {
                wid: w.id,
                pod: w.pod,
                queue: w.queue.clone(),
                r: QuotaVec::of(&p.spec.resources),
                admitted_at: w.admitted_at.unwrap_or(0.0),
            });
        }
        v.sort_by(|a, b| {
            b.admitted_at
                .total_cmp(&a.admitted_at)
                .then(b.wid.cmp(&a.wid))
        });
        // Workload granularity is atomic, so the last victim per queue
        // may cross the nominal boundary (upstream Kueue allows the
        // same); the cap just stops planning once a queue no longer
        // borrows in any dimension the victim would repay. One
        // algorithm, one place: the same `live_eligible` walk re-prunes
        // the stage-5 cache mid-cycle, and their equivalence is what
        // makes cache-equals-recompute exact.
        let keep: BTreeSet<PodId> =
            self.live_eligible(&v[..]).into_iter().collect();
        v.retain(|c| keep.contains(&c.pod));
        v
    }

    /// Re-trim a cycle-start candidate list against LIVE per-queue
    /// borrow state: walk junior-first, keeping a candidate only while
    /// its queue still borrows in a dimension the eviction would repay
    /// (the same cap walk [`Kueue::reclaim_candidates`] applies at
    /// build time). Borrowed amounts only shrink within a cycle, so
    /// this O(cands) pass over the cached superset yields exactly what
    /// a full per-workload recompute would.
    fn live_eligible(&self, cands: &[ReclaimCandidate]) -> Vec<PodId> {
        let mut remaining: BTreeMap<&str, QuotaVec> = BTreeMap::new();
        let mut out = Vec::with_capacity(cands.len());
        for c in cands {
            let rem = remaining
                .entry(c.queue.as_str())
                .or_insert_with(|| self.queues[&c.queue].borrowed());
            if rem.overlaps(c.r) {
                *rem = rem.saturating_sub(c.r);
                out.push(c.pod);
            }
        }
        out
    }

    /// The junior-first subset of `cands` (as ascending indices) whose
    /// eviction makes admitting `r` into `into_queue` cohort-feasible
    /// (empty = already feasible), or None if no subset does. A
    /// candidate is only chosen if it can repay a currently-blocked
    /// dimension: evicting a GPU-only borrower for a CPU deficit is a
    /// wasted eviction, and since evictions shrink the deficit
    /// monotonically (borrowed falls, lendable never falls), a
    /// candidate skipped now can never become necessary later.
    fn quota_reclaim_victims(
        &self,
        cohort: &str,
        into_queue: &str,
        r: QuotaVec,
        cands: &[ReclaimCandidate],
    ) -> Option<Vec<usize>> {
        let members: Vec<&str> = match self.cohorts.get(cohort) {
            Some(c) => c.members().collect(),
            None => return None,
        };
        let mut used: BTreeMap<&str, QuotaVec> = members
            .iter()
            .map(|&m| (m, self.queues[m].used))
            .collect();
        if let Some(u) = used.get_mut(into_queue) {
            *u = u.add(r);
        }
        let totals = |used: &BTreeMap<&str, QuotaVec>| {
            let mut borrowed = QuotaVec::ZERO;
            let mut lendable = QuotaVec::ZERO;
            for &m in &members {
                let q = &self.queues[m];
                if let Some(n) = q.nominal {
                    let (b, l) = borrow_lend(used[m], n, q.lending_limit);
                    borrowed = borrowed.add(b);
                    lendable = lendable.add(l);
                }
            }
            (borrowed, lendable)
        };
        let (mut borrowed, mut lendable) = totals(&used);
        if borrowed.fits_within(lendable) {
            return Some(Vec::new());
        }
        let mut chosen = Vec::new();
        for (k, c) in cands.iter().enumerate() {
            let deficit = borrowed.saturating_sub(lendable);
            if !c.r.overlaps(deficit) {
                continue; // cannot even touch a blocked dimension
            }
            // Touching a blocked dimension is necessary but not
            // sufficient: the victim's queue may not be borrowing — or
            // not allowed to lend — in that dimension, in which case
            // its eviction repays nothing. Commit only on actual
            // progress; since evictions shrink the deficit
            // monotonically and a queue's repayment capacity in a
            // dimension only grows as its usage falls, a candidate
            // making no progress now can never make progress later.
            let before = used[c.queue.as_str()];
            if let Some(u) = used.get_mut(c.queue.as_str()) {
                *u = u.saturating_sub(c.r);
            }
            let (b2, l2) = totals(&used);
            if b2.saturating_sub(l2) == deficit {
                if let Some(u) = used.get_mut(c.queue.as_str()) {
                    *u = before; // no progress; spare the victim
                }
                continue;
            }
            borrowed = b2;
            lendable = l2;
            chosen.push(k);
            if borrowed.fits_within(lendable) {
                return Some(chosen);
            }
        }
        None
    }

    /// Evict one borrowing workload on the reclaim path: release its
    /// quota, requeue it at the front (it keeps seniority, like
    /// notebook preemption), and stamp the distinct reason.
    fn reclaim_evict(
        &mut self,
        cluster: &mut Cluster,
        wid: WorkloadId,
        pod: PodId,
    ) {
        if cluster.evict(pod).is_err() {
            return;
        }
        self.n_evictions += 1;
        self.n_reclaim_evictions += 1;
        if let Some(p) = cluster.pod(pod) {
            let r = QuotaVec::of(&p.spec.resources);
            let q = self.queues.get_mut(&self.workloads[&wid].queue).unwrap();
            q.used = q.used.saturating_sub(r);
        }
        let w = self.workloads.get_mut(&wid).unwrap();
        w.state = WorkloadState::Queued;
        w.admitted_at = None;
        w.assigned_node = None;
        w.requeues += 1;
        w.preempted_by = Some(PreemptReason::ReclaimBorrowed);
        w.refused_epoch = None;
        self.pending.push_front(wid);
        self.dirty = true;
    }

    /// §4 contention path: a notebook pod cannot fit → evict enough
    /// batch pods (per the scheduler's preemption plan), requeue their
    /// workloads, and bind the notebook. Returns evicted workload ids.
    pub fn make_room_for_notebook(
        &mut self,
        cluster: &mut Cluster,
        scheduler: &Scheduler,
        notebook_pod: PodId,
    ) -> Result<(NodeId, Vec<WorkloadId>), String> {
        let (node, victims) = scheduler
            .plan_preemption(cluster, notebook_pod)
            .ok_or("no preemption plan frees enough resources")?;
        let mut evicted = Vec::new();
        for pod in victims {
            cluster.evict(pod)?;
            self.n_evictions += 1;
            // Requeue the owning workload (if the pod is Kueue-managed).
            let owner = self.pod_owner.get(&pod).copied().filter(|wid| {
                self.workloads
                    .get(wid)
                    .map(|w| w.pod == pod && w.state == WorkloadState::Admitted)
                    .unwrap_or(false)
            });
            let wid = match owner {
                Some(wid) => wid,
                None => continue,
            };
            // Release local quota.
            if let Some(p) = cluster.pod(pod) {
                let r = QuotaVec::of(&p.spec.resources);
                let q =
                    self.queues.get_mut(&self.workloads[&wid].queue).unwrap();
                q.used = q.used.saturating_sub(r);
            }
            let w = self.workloads.get_mut(&wid).unwrap();
            w.state = WorkloadState::Queued;
            w.admitted_at = None;
            w.assigned_node = None;
            w.requeues += 1;
            w.preempted_by = Some(PreemptReason::NotebookPriority);
            w.refused_epoch = None;
            evicted.push(wid);
        }
        // Requeue evicted workloads at the FRONT (they keep seniority),
        // preserving their original relative order.
        for id in evicted.iter().rev() {
            // The evicted pod is terminal; the owner resubmits a clone.
            self.pending.push_front(*id);
        }
        if !evicted.is_empty() {
            self.dirty = true;
        }
        cluster.bind_to(notebook_pod, node)?;
        Ok((node, evicted))
    }

    /// Fault-recovery path: requeue workloads whose pods an injected
    /// fault has ALREADY evicted (node drain, GPU device failure —
    /// the `chaos` layer). Pods with no Kueue workload (directly bound
    /// fillers, notebooks) are skipped — the cluster already evicted
    /// them and nothing respawns them.
    ///
    /// Each affected workload releases its local quota, is stamped
    /// [`PreemptReason::FaultEviction`], and either:
    /// - requeues at the FRONT (seniority preserved, like notebook
    ///   preemption) with `not_before = now + base · 2^(k-1)` where
    ///   `k` is its fault-requeue count — exponential backoff whose
    ///   *effective* retry instants land on the admission grid in both
    ///   loop modes; or
    /// - goes terminal-Failed once `fault_requeues` exceeds
    ///   `retry_budget`, with the reason stamped on its (Evicted) pod.
    ///
    /// Returns `(requeued, exhausted)` workload ids, in pod order.
    /// The caller follows up with [`Kueue::respawn_evicted_pods`].
    pub fn requeue_faulted(
        &mut self,
        cluster: &mut Cluster,
        pods: &[PodId],
        now: Time,
        backoff_base_s: f64,
        retry_budget: u32,
    ) -> (Vec<WorkloadId>, Vec<WorkloadId>) {
        let mut requeued = Vec::new();
        let mut exhausted = Vec::new();
        for &pod in pods {
            let wid = match self.pod_owner.get(&pod).copied().filter(|wid| {
                self.workloads
                    .get(wid)
                    .map(|w| {
                        w.pod == pod && w.state == WorkloadState::Admitted
                    })
                    .unwrap_or(false)
            }) {
                Some(wid) => wid,
                None => continue, // not Kueue-managed (filler, notebook)
            };
            // Release local quota. The assigned node may already be
            // gone (a crash removes it); chaos never removes virtual
            // nodes, so a missing node was local.
            let was_local = self.workloads[&wid]
                .assigned_node
                .map(|n| {
                    cluster.node_by_id(n).map_or(true, |n| !n.virtual_node)
                })
                .unwrap_or(false);
            if was_local {
                if let Some(p) = cluster.pod(pod) {
                    let r = QuotaVec::of(&p.spec.resources);
                    let q = self
                        .queues
                        .get_mut(&self.workloads[&wid].queue)
                        .unwrap();
                    q.used = q.used.saturating_sub(r);
                }
            }
            self.n_fault_evictions += 1;
            let w = self.workloads.get_mut(&wid).unwrap();
            w.admitted_at = None;
            w.assigned_node = None;
            w.preempted_by = Some(PreemptReason::FaultEviction);
            w.refused_epoch = None;
            w.fault_requeues += 1;
            if w.fault_requeues > retry_budget {
                w.state = WorkloadState::Failed;
                w.finished_at = Some(now);
                w.not_before = None;
                w.fault_evicted_at = None;
                self.n_retry_exhausted += 1;
                if let Some(p) = cluster.pod_mut(pod) {
                    p.failure_reason =
                        Some("fault retry budget exhausted".to_string());
                }
                exhausted.push(wid);
            } else {
                let k = (w.fault_requeues - 1).min(16);
                w.state = WorkloadState::Queued;
                w.requeues += 1;
                w.not_before = Some(now + backoff_base_s * (1u64 << k) as f64);
                w.fault_evicted_at = Some(now);
                requeued.push(wid);
            }
        }
        // Requeue at the FRONT preserving relative (seniority) order.
        for id in requeued.iter().rev() {
            self.pending.push_front(*id);
        }
        if !requeued.is_empty() || !exhausted.is_empty() {
            self.dirty = true;
        }
        (requeued, exhausted)
    }

    /// Mark a workload finished (its pod completed) and release quota.
    pub fn finish(
        &mut self,
        cluster: &Cluster,
        id: WorkloadId,
        ok: bool,
        now: Time,
    ) -> Result<(), String> {
        let w = self
            .workloads
            .get_mut(&id)
            .ok_or_else(|| format!("no workload {id:?}"))?;
        if w.state != WorkloadState::Admitted {
            return Err(format!("workload {id:?} not admitted"));
        }
        let was_local = w
            .assigned_node
            .and_then(|n| cluster.node_by_id(n))
            .map(|n| !n.virtual_node)
            .unwrap_or(false);
        if was_local {
            if let Some(p) = cluster.pod(w.pod) {
                let r = QuotaVec::of(&p.spec.resources);
                let q = self.queues.get_mut(&self.workloads[&id].queue).unwrap();
                q.used = q.used.saturating_sub(r);
            }
        }
        let w = self.workloads.get_mut(&id).unwrap();
        w.state = if ok { WorkloadState::Finished } else { WorkloadState::Failed };
        w.finished_at = Some(now);
        // Quota (if local) was released above; pending workloads in the
        // same queue — or cohort — may now fit.
        self.dirty = true;
        Ok(())
    }

    /// Re-create pods for requeued workloads whose pods are terminal
    /// (eviction kills the pod; Kueue resubmits a fresh one).
    pub fn respawn_evicted_pods(&mut self, cluster: &mut Cluster) {
        let ids: Vec<WorkloadId> = self.pending.iter().copied().collect();
        for id in ids {
            let w = self.workloads.get_mut(&id).unwrap();
            let needs_new_pod = cluster
                .pod(w.pod)
                .map(|p| p.phase == PodPhase::Evicted)
                .unwrap_or(false);
            if needs_new_pod {
                let spec = cluster.pod(w.pod).unwrap().spec.clone();
                let new_pod = cluster.create_pod(spec);
                self.pod_owner.remove(&w.pod);
                self.pod_owner.insert(new_pod, id);
                w.pod = new_pod;
                self.dirty = true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Node, PodSpec, Resources, ScheduleError};
    use crate::util::bytes::GIB;

    fn farm() -> (Cluster, Scheduler, Kueue) {
        let mut c = Cluster::new();
        c.add_node(Node::physical("n1", 8_000, 32 * GIB, GIB, &[]));
        (c, Scheduler::new(), Kueue::new())
    }

    fn batch_pod(c: &mut Cluster, cpu_m: u64) -> PodId {
        c.create_pod(PodSpec::batch("u", Resources::cpu_mem(cpu_m, GIB), "job"))
    }

    fn submit_batch(
        c: &mut Cluster,
        k: &mut Kueue,
        queue: &str,
        cpu_m: u64,
    ) -> WorkloadId {
        let p = batch_pod(c, cpu_m);
        k.submit(p, queue, "u", false, 0.0).unwrap()
    }

    #[test]
    fn fifo_admission_until_capacity() {
        let (mut c, s, mut k) = farm();
        let mut ids = Vec::new();
        for _ in 0..5 {
            let p = batch_pod(&mut c, 3_000); // node fits 2 of these
            ids.push(k.submit(p, "local-batch", "u", false, 0.0).unwrap());
        }
        let admitted = k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(admitted, vec![ids[0], ids[1]]);
        assert_eq!(k.pending_count(), 3);
        assert_eq!(k.n_admitted_local, 2);
    }

    #[test]
    fn quota_limits_admission_even_with_capacity() {
        let (mut c, s, mut k) = farm();
        k.add_queue(ClusterQueue::with_nominal("capped", QuotaVec::cpu(3_000)));
        submit_batch(&mut c, &mut k, "capped", 2_000);
        submit_batch(&mut c, &mut k, "capped", 2_000);
        let admitted = k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(admitted.len(), 1); // quota 3000m, each needs 2000m
        k.check_cohort_invariants().unwrap();
    }

    #[test]
    fn notebook_contention_evicts_batch_and_requeues() {
        let (mut c, s, mut k) = farm();
        // Fill the node with batch.
        let p1 = batch_pod(&mut c, 4_000);
        let p2 = batch_pod(&mut c, 4_000);
        let w1 = k.submit(p1, "local-batch", "u", false, 0.0).unwrap();
        let w2 = k.submit(p2, "local-batch", "u", false, 0.0).unwrap();
        k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(c.running_pods(), 2);

        // Notebook arrives; no room.
        let nb = c.create_pod(PodSpec::notebook(
            "rosa",
            Resources::cpu_mem(6_000, 8 * GIB),
        ));
        assert!(matches!(
            s.place(&c, nb, ScoringPolicy::BinPack),
            Err(ScheduleError::NoCapacity)
        ));
        let (_, evicted) =
            k.make_room_for_notebook(&mut c, &s, nb).unwrap();
        assert!(!evicted.is_empty());
        assert_eq!(c.pod(nb).unwrap().phase, PodPhase::Running);
        assert_eq!(k.n_evictions as usize, evicted.len());
        // Evicted workloads are queued again with seniority, and the
        // eviction is stamped with the notebook reason.
        assert!(evicted.iter().all(|id| {
            let w = k.workload(*id).unwrap();
            w.state == WorkloadState::Queued
                && w.preempted_by == Some(PreemptReason::NotebookPriority)
        }));
        assert!(k.pending.front().map(|f| evicted.contains(f)).unwrap_or(false));
        let _ = (w1, w2);
        c.check_accounting().unwrap();
    }

    #[test]
    fn respawn_creates_fresh_pods_for_evicted() {
        let (mut c, s, mut k) = farm();
        let p1 = batch_pod(&mut c, 8_000);
        let w1 = k.submit(p1, "local-batch", "u", false, 0.0).unwrap();
        k.admission_cycle(&mut c, &s, 1.0);
        let nb = c.create_pod(PodSpec::notebook(
            "rosa",
            Resources::cpu_mem(2_000, GIB),
        ));
        k.make_room_for_notebook(&mut c, &s, nb).unwrap();
        k.respawn_evicted_pods(&mut c);
        let new_pod = k.workload(w1).unwrap().pod;
        assert_ne!(new_pod, p1);
        assert_eq!(c.pod(new_pod).unwrap().phase, PodPhase::Pending);
        // And it can be admitted once capacity allows.
        c.complete(nb).unwrap();
        let admitted = k.admission_cycle(&mut c, &s, 2.0);
        assert_eq!(admitted, vec![w1]);
    }

    #[test]
    fn finish_releases_quota() {
        let (mut c, s, mut k) = farm();
        k.add_queue(ClusterQueue::with_nominal("capped", QuotaVec::cpu(4_000)));
        let p1 = batch_pod(&mut c, 4_000);
        let w1 = k.submit(p1, "capped", "u", false, 0.0).unwrap();
        k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(k.queue("capped").unwrap().used, QuotaVec::cpu(4_000));
        c.complete(p1).unwrap();
        k.finish(&c, w1, true, 10.0).unwrap();
        assert_eq!(k.queue("capped").unwrap().used, QuotaVec::ZERO);
        assert_eq!(
            k.workload(w1).unwrap().state,
            WorkloadState::Finished
        );
    }

    #[test]
    fn offload_compatible_workload_reaches_virtual_node_when_local_full() {
        let (mut c, s, mut k) = farm();
        c.add_node(Node::virtual_node("vk-leonardo", "leonardo", 1_000_000, 1024 * GIB));
        // Fill local.
        let filler = batch_pod(&mut c, 8_000);
        k.submit(filler, "local-batch", "u", false, 0.0).unwrap();
        k.admission_cycle(&mut c, &s, 0.5);
        // Offload-compatible job: tolerates virtual nodes.
        let mut spec = PodSpec::batch("u", Resources::cpu_mem(4_000, GIB), "fs");
        spec.offload_compatible = true;
        spec.tolerations.push("interlink.virtual-node".into());
        let p = c.create_pod(spec);
        let w = k.submit(p, "local-batch", "u", true, 1.0).unwrap();
        let admitted = k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(admitted, vec![w]);
        assert_eq!(
            k.workload(w).unwrap().assigned_node.map(|n| c.name_of(n)),
            Some("vk-leonardo")
        );
        assert_eq!(k.n_admitted_virtual, 1);
        // Non-offloadable job stays pending.
        let p2 = batch_pod(&mut c, 4_000);
        k.submit(p2, "local-batch", "u", false, 2.0).unwrap();
        assert!(k.admission_cycle(&mut c, &s, 2.0).is_empty());
        assert_eq!(k.pending_count(), 1);
    }

    #[test]
    fn requeued_workloads_keep_seniority_under_indexed_path() {
        let (mut c, s, mut k) = farm();
        assert_eq!(s.mode, crate::cluster::PlacementMode::Indexed);
        // Two admitted workloads fill the node; two more wait behind.
        let mut wls = Vec::new();
        for _ in 0..4 {
            let p = batch_pod(&mut c, 4_000);
            wls.push(k.submit(p, "local-batch", "u", false, 0.0).unwrap());
        }
        k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(k.pending_ids(), vec![wls[2], wls[3]]);
        // Notebook contention evicts both admitted workloads: they must
        // re-enter at the FRONT, in their original relative order.
        let nb = c.create_pod(PodSpec::notebook(
            "rosa",
            Resources::cpu_mem(8_000, 8 * GIB),
        ));
        let (_, evicted) = k.make_room_for_notebook(&mut c, &s, nb).unwrap();
        assert_eq!(evicted, vec![wls[0], wls[1]]);
        assert_eq!(k.pending_ids(), vec![wls[0], wls[1], wls[2], wls[3]]);
        // After respawn + capacity returning, the oldest admits first
        // and the pod→workload map tracks the fresh pod.
        k.respawn_evicted_pods(&mut c);
        for w in [wls[0], wls[1]] {
            let pod = k.workload(w).unwrap().pod;
            assert_eq!(k.workload_of_pod(pod), Some(w));
        }
        c.complete(nb).unwrap();
        let admitted = k.admission_cycle(&mut c, &s, 2.0);
        assert_eq!(admitted, vec![wls[0], wls[1]]);
        c.check_index().unwrap();
    }

    #[test]
    fn submit_to_unknown_queue_fails() {
        let (mut c, _, mut k) = farm();
        let p = batch_pod(&mut c, 1_000);
        assert!(k.submit(p, "nope", "u", false, 0.0).is_err());
    }

    // ---- quota-tree semantics ----

    /// Two queues in one cohort: the borrower rides the owner's idle
    /// nominal quota and the whole thing stays invariant-clean.
    #[test]
    fn borrowing_uses_idle_cohort_quota() {
        let (mut c, s, mut k) = farm();
        k.add_queue(
            ClusterQueue::with_nominal("owner", QuotaVec::cpu(4_000))
                .in_cohort("tenants"),
        );
        k.add_queue(
            ClusterQueue::with_nominal("borrower", QuotaVec::cpu(1_000))
                .in_cohort("tenants"),
        );
        let w1 = submit_batch(&mut c, &mut k, "borrower", 2_000);
        let w2 = submit_batch(&mut c, &mut k, "borrower", 2_000);
        let admitted = k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(admitted, vec![w1, w2], "idle owner quota is borrowable");
        assert_eq!(k.n_admitted_borrow, 2);
        assert_eq!(
            k.queue("borrower").unwrap().borrowed(),
            QuotaVec::cpu(3_000)
        );
        let u = k.cohort_usage("tenants");
        assert_eq!(u.capacity, QuotaVec::cpu(5_000));
        assert_eq!(u.used, QuotaVec::cpu(4_000));
        k.check_cohort_invariants().unwrap();
    }

    /// A lender's `lending_limit` caps how deep borrowers can reach.
    #[test]
    fn lending_limit_caps_borrowing() {
        let (mut c, s, mut k) = farm();
        k.add_queue(
            ClusterQueue::with_nominal("owner", QuotaVec::cpu(4_000))
                .in_cohort("tenants")
                .lending(QuotaVec::cpu(1_000)),
        );
        k.add_queue(
            ClusterQueue::with_nominal("borrower", QuotaVec::cpu(1_000))
                .in_cohort("tenants"),
        );
        let w1 = submit_batch(&mut c, &mut k, "borrower", 2_000);
        submit_batch(&mut c, &mut k, "borrower", 2_000);
        let admitted = k.admission_cycle(&mut c, &s, 1.0);
        // First job borrows 1000m (at the lending limit); the second
        // would need 3000m borrowed > 1000m lendable.
        assert_eq!(admitted, vec![w1]);
        assert_eq!(k.pending_count(), 1);
        k.check_cohort_invariants().unwrap();
    }

    /// A borrower's own `borrowing_limit` caps it even when the cohort
    /// has more to lend.
    #[test]
    fn borrowing_limit_caps_borrower() {
        let (mut c, s, mut k) = farm();
        k.add_queue(
            ClusterQueue::with_nominal("owner", QuotaVec::cpu(6_000))
                .in_cohort("tenants"),
        );
        k.add_queue(
            ClusterQueue::with_nominal("borrower", QuotaVec::cpu(1_000))
                .in_cohort("tenants")
                .borrowing(QuotaVec::cpu(2_000)),
        );
        let w1 = submit_batch(&mut c, &mut k, "borrower", 3_000);
        submit_batch(&mut c, &mut k, "borrower", 3_000);
        let admitted = k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(admitted, vec![w1], "1000 nominal + 2000 borrowing limit");
        assert_eq!(k.pending_count(), 1);
        k.check_cohort_invariants().unwrap();
    }

    /// The tentpole scenario at unit scale: borrowers exhaust the
    /// cohort AND the farm; the owner's wave reclaims its nominal
    /// quota by evicting the most-junior borrowers.
    #[test]
    fn reclaim_restores_owner_nominal_quota() {
        let (mut c, s, mut k) = farm();
        k.add_queue(
            ClusterQueue::with_nominal("owner", QuotaVec::cpu(6_000))
                .in_cohort("tenants"),
        );
        k.add_queue(
            ClusterQueue::with_nominal("borrower", QuotaVec::cpu(2_000))
                .in_cohort("tenants"),
        );
        // Borrower saturates the 8000m node: 2000 nominal + 6000 borrowed.
        let mut borrower_wls = Vec::new();
        for _ in 0..4 {
            borrower_wls.push(submit_batch(&mut c, &mut k, "borrower", 2_000));
        }
        assert_eq!(k.admission_cycle(&mut c, &s, 1.0).len(), 4);
        assert_eq!(k.queue("borrower").unwrap().borrowed(), QuotaVec::cpu(6_000));
        k.check_cohort_invariants().unwrap();

        // The owner's wave: 3 × 2000m, all within its nominal quota.
        let mut owner_wls = Vec::new();
        for _ in 0..3 {
            owner_wls.push(submit_batch(&mut c, &mut k, "owner", 2_000));
        }
        let admitted = k.admission_cycle(&mut c, &s, 2.0);
        assert_eq!(admitted, owner_wls, "owner reclaims in one cycle");
        assert_eq!(k.queue("owner").unwrap().used, QuotaVec::cpu(6_000));
        assert_eq!(k.queue("borrower").unwrap().used, QuotaVec::cpu(2_000));
        assert_eq!(k.n_reclaim_evictions, 3);
        assert_eq!(k.n_admitted_reclaim, 3);
        // Most-junior borrowers went first and carry the reclaim stamp;
        // their pods were respawned (Pending clones), keeping them queued.
        assert_eq!(k.pending_count(), 3);
        for wid in k.pending_ids() {
            let w = k.workload(wid).unwrap();
            assert!(borrower_wls.contains(&wid));
            assert_eq!(w.state, WorkloadState::Queued);
            assert_eq!(w.preempted_by, Some(PreemptReason::ReclaimBorrowed));
            assert_eq!(
                c.pod(w.pod).map(|p| p.phase),
                Some(PodPhase::Pending),
                "reclaim respawns the victim's pod"
            );
        }
        // The most-senior borrower survived.
        assert_eq!(
            k.workload(borrower_wls[0]).unwrap().state,
            WorkloadState::Admitted
        );
        k.check_cohort_invariants().unwrap();
        c.check_accounting().unwrap();

        // Next cycle: borrowers cannot re-borrow (no lendable headroom
        // left) — the reclaimed state is stable.
        assert!(k.admission_cycle(&mut c, &s, 3.0).is_empty());
        assert_eq!(k.queue("owner").unwrap().used, QuotaVec::cpu(6_000));
        k.check_cohort_invariants().unwrap();
    }

    /// Reclaim fires even when the farm has physical room: cohort
    /// quota alone can exhaust (the borrower holds the whole cohort
    /// capacity while the node still has free CPU).
    #[test]
    fn reclaim_fires_on_pure_quota_exhaustion() {
        let mut c = Cluster::new();
        c.add_node(Node::physical("n1", 16_000, 64 * GIB, GIB, &[]));
        let (s, mut k) = (Scheduler::new(), Kueue::new());
        k.add_queue(
            ClusterQueue::with_nominal("owner", QuotaVec::cpu(6_000))
                .in_cohort("tenants"),
        );
        k.add_queue(
            ClusterQueue::with_nominal("borrower", QuotaVec::cpu(2_000))
                .in_cohort("tenants"),
        );
        for _ in 0..4 {
            submit_batch(&mut c, &mut k, "borrower", 2_000);
        }
        assert_eq!(k.admission_cycle(&mut c, &s, 1.0).len(), 4);
        // 8000m free on the node, but the cohort's 8000m capacity is
        // fully used — the owner must reclaim, not just place.
        let w = submit_batch(&mut c, &mut k, "owner", 2_000);
        let admitted = k.admission_cycle(&mut c, &s, 2.0);
        assert_eq!(admitted, vec![w]);
        assert_eq!(k.n_reclaim_evictions, 1);
        k.check_cohort_invariants().unwrap();
    }

    /// Serving a starving owner in stage 3 un-freezes its cohort for
    /// borrowers the same cycle passed over — the admission itself
    /// must raise the dirty edge so the reactive loop retries on the
    /// next grid instant exactly like polling would (cross-mode
    /// byte-equality regression).
    #[test]
    fn admission_unfreezes_starved_cohort_next_cycle() {
        let (mut c, s, mut k) = farm();
        k.add_queue(
            ClusterQueue::with_nominal("owner", QuotaVec::cpu(4_000))
                .in_cohort("tenants"),
        );
        k.add_queue(
            ClusterQueue::with_nominal("borrower", QuotaVec::cpu(1_000))
                .in_cohort("tenants"),
        );
        let ow = submit_batch(&mut c, &mut k, "owner", 2_000);
        let bw = submit_batch(&mut c, &mut k, "borrower", 2_000);
        k.take_dirty();
        let admitted = k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(
            admitted,
            vec![ow],
            "borrower frozen by the stage-1 starved snapshot"
        );
        assert!(
            k.take_dirty(),
            "the admission must re-arm the reactive loop for the \
             passed-over borrower"
        );
        let admitted = k.admission_cycle(&mut c, &s, 2.0);
        assert_eq!(admitted, vec![bw], "borrower admitted next cycle");
        assert!(!k.take_dirty(), "nothing pending → cascade terminates");
        k.check_cohort_invariants().unwrap();
    }

    /// Reclaim must not evict borrowers for an owner pod that cannot
    /// be physically placed even after evicting every candidate (the
    /// blocked dimension is memory, which no quota eviction repays).
    #[test]
    fn reclaim_never_evicts_for_unplaceable_pod() {
        let (mut c, s, mut k) = farm();
        k.add_queue(
            ClusterQueue::with_nominal("owner", QuotaVec::cpu(6_000))
                .in_cohort("tenants"),
        );
        k.add_queue(
            ClusterQueue::with_nominal("borrower", QuotaVec::cpu(2_000))
                .in_cohort("tenants"),
        );
        for _ in 0..4 {
            submit_batch(&mut c, &mut k, "borrower", 2_000);
        }
        assert_eq!(k.admission_cycle(&mut c, &s, 1.0).len(), 4);
        // Within CPU quota, but needs more memory than any node owns.
        let p = c.create_pod(PodSpec::batch(
            "u",
            Resources::cpu_mem(2_000, 64 * GIB),
            "job",
        ));
        k.submit(p, "owner", "u", false, 2.0).unwrap();
        assert!(k.admission_cycle(&mut c, &s, 2.0).is_empty());
        assert_eq!(
            k.n_reclaim_evictions, 0,
            "no eviction without physical reachability"
        );
        assert_eq!(k.queue("borrower").unwrap().used, QuotaVec::cpu(8_000));
        k.check_cohort_invariants().unwrap();
    }

    /// Mixed-dimension cohorts: a CPU deficit must be repaid by
    /// CPU-borrowing victims — the most-junior borrower is spared when
    /// it only borrows GPUs (no wasted cross-dimension evictions).
    #[test]
    fn reclaim_victims_must_repay_the_blocked_dimension() {
        let mut c = Cluster::new();
        c.add_node(crate::cluster::Node::physical(
            "n1",
            8_000,
            32 * GIB,
            GIB,
            &[(crate::cluster::GpuModel::TeslaT4, 2)],
        ));
        let (s, mut k) = (Scheduler::new(), Kueue::new());
        k.add_queue(
            ClusterQueue::with_nominal("owner", QuotaVec::new(4_000, 2))
                .in_cohort("tenants"),
        );
        k.add_queue(
            ClusterQueue::with_nominal("gpu-tenant", QuotaVec::ZERO)
                .in_cohort("tenants"),
        );
        k.add_queue(
            ClusterQueue::with_nominal("cpu-tenant", QuotaVec::cpu(1_000))
                .in_cohort("tenants"),
        );
        // cpu-tenant borrows 3000m CPU (2 × 2000m jobs over 1000m
        // nominal)...
        let cpu_wls = [
            submit_batch(&mut c, &mut k, "cpu-tenant", 2_000),
            submit_batch(&mut c, &mut k, "cpu-tenant", 2_000),
        ];
        assert_eq!(k.admission_cycle(&mut c, &s, 1.0).len(), 2);
        // ...then the gpu-tenant borrows one device (zero CPU), making
        // it the most-junior borrower in the cohort.
        let gpu_pod = c.create_pod(PodSpec::batch(
            "u",
            Resources {
                gpus: 1,
                ..Resources::cpu_mem(0, GIB)
            },
            "job",
        ));
        let gpu_wl = k.submit(gpu_pod, "gpu-tenant", "u", false, 2.0).unwrap();
        assert_eq!(k.admission_cycle(&mut c, &s, 2.0), vec![gpu_wl]);
        k.check_cohort_invariants().unwrap();
        // The owner's CPU claim: the deficit is CPU-only, so reclaim
        // must evict the junior *CPU* borrower and spare the GPU one.
        let ow = submit_batch(&mut c, &mut k, "owner", 2_000);
        assert_eq!(k.admission_cycle(&mut c, &s, 3.0), vec![ow]);
        assert_eq!(k.n_reclaim_evictions, 1);
        assert_eq!(
            k.workload(gpu_wl).unwrap().state,
            WorkloadState::Admitted,
            "GPU-only borrower wrongly evicted for a CPU deficit"
        );
        assert_eq!(
            k.workload(cpu_wls[1]).unwrap().state,
            WorkloadState::Queued,
            "the junior CPU borrower repays the deficit"
        );
        k.check_cohort_invariants().unwrap();
        c.check_accounting().unwrap();
    }

    /// Touching the blocked dimension is not enough: a tenant whose
    /// job consumes CPU *below its own CPU nominal* (and lends
    /// nothing) while borrowing only GPUs repays nothing toward a CPU
    /// deficit — it must be spared even though its request vector
    /// overlaps the deficit.
    #[test]
    fn reclaim_spares_victims_whose_eviction_repays_nothing() {
        let mut c = Cluster::new();
        c.add_node(crate::cluster::Node::physical(
            "n1",
            16_000,
            64 * GIB,
            GIB,
            &[(crate::cluster::GpuModel::TeslaT4, 2)],
        ));
        let (s, mut k) = (Scheduler::new(), Kueue::new());
        k.add_queue(
            ClusterQueue::with_nominal("owner", QuotaVec::new(6_000, 2))
                .in_cohort("tenants"),
        );
        // Mixed tenant: generous CPU nominal it never fills, zero
        // lending — so its eviction can never repay a CPU deficit.
        k.add_queue(
            ClusterQueue::with_nominal("mixed", QuotaVec::cpu(4_000))
                .in_cohort("tenants")
                .lending(QuotaVec::ZERO),
        );
        k.add_queue(
            ClusterQueue::with_nominal("cpu-tenant", QuotaVec::cpu(1_000))
                .in_cohort("tenants"),
        );
        let cpu_wls = [
            submit_batch(&mut c, &mut k, "cpu-tenant", 2_000),
            submit_batch(&mut c, &mut k, "cpu-tenant", 2_000),
        ];
        assert_eq!(k.admission_cycle(&mut c, &s, 1.0).len(), 2);
        // The junior-most borrower: 2000m CPU (under mixed's nominal)
        // plus one borrowed GPU.
        let mixed_pod = c.create_pod(PodSpec::batch(
            "u",
            Resources {
                gpus: 1,
                ..Resources::cpu_mem(2_000, GIB)
            },
            "job",
        ));
        let mixed_wl = k.submit(mixed_pod, "mixed", "u", false, 2.0).unwrap();
        assert_eq!(k.admission_cycle(&mut c, &s, 2.0), vec![mixed_wl]);
        k.check_cohort_invariants().unwrap();
        // The owner's full CPU wave: only the cpu-tenant's borrowers
        // can repay the resulting CPU deficit.
        let owner_wls = [
            submit_batch(&mut c, &mut k, "owner", 2_000),
            submit_batch(&mut c, &mut k, "owner", 2_000),
            submit_batch(&mut c, &mut k, "owner", 2_000),
        ];
        let admitted = k.admission_cycle(&mut c, &s, 3.0);
        assert_eq!(admitted, owner_wls);
        assert_eq!(k.n_reclaim_evictions, 2, "one per CPU borrower");
        assert_eq!(
            k.workload(mixed_wl).unwrap().state,
            WorkloadState::Admitted,
            "mixed tenant wrongly evicted: its eviction repays nothing"
        );
        for wl in cpu_wls {
            assert_eq!(k.workload(wl).unwrap().state, WorkloadState::Queued);
        }
        k.check_cohort_invariants().unwrap();
        c.check_accounting().unwrap();
    }

    /// Per-GPU-model quota dimensions: a cohort rations
    /// A100-equivalents separately from T4s, and carved partitions
    /// draw down the same entitlement as whole devices.
    #[test]
    fn slice_weighted_model_dimensions_ration_independently() {
        use crate::cluster::{GpuModel, SliceProfile};
        let mut c = Cluster::new();
        c.add_node(crate::cluster::Node::physical(
            "g1",
            64_000,
            256 * GIB,
            crate::util::bytes::TIB,
            &[(GpuModel::A100, 2), (GpuModel::TeslaT4, 2)],
        ));
        let (s, mut k) = (Scheduler::new(), Kueue::new());
        // One A100 worth of units (7) and one T4 worth (4), plus CPU.
        k.add_queue(
            ClusterQueue::with_nominal(
                "ml-tenant",
                QuotaVec::cpu(32_000)
                    .with_whole_gpus(GpuModel::A100, 1)
                    .with_gpu_units(GpuModel::TeslaT4, 4),
            ),
        );
        let slice_pod = |c: &mut Cluster, model, profile| {
            c.create_pod(PodSpec::batch(
                "u",
                Resources {
                    nvme: 0,
                    ..Resources::notebook_gpu_slice(model, profile)
                },
                "train",
            ))
        };
        // Four A100 slices (2 units each) — the fourth would exceed
        // the 7-unit A100 grant and must stay pending even though the
        // farm has room (2 devices = 14 units) and the T4 dimension
        // is idle.
        let mut wls = Vec::new();
        for _ in 0..4 {
            let p = slice_pod(&mut c, GpuModel::A100, SliceProfile::Mig2g10gb);
            wls.push(k.submit(p, "ml-tenant", "u", false, 0.0).unwrap());
        }
        let admitted = k.admission_cycle(&mut c, &s, 1.0);
        assert_eq!(
            admitted,
            vec![wls[0], wls[1], wls[2]],
            "6 of 7 A100 units used"
        );
        assert_eq!(k.pending_count(), 1);
        // The T4 dimension is independent: time-slice replicas admit.
        let t4 = slice_pod(&mut c, GpuModel::TeslaT4, SliceProfile::TsQuarter);
        let t4_wl = k.submit(t4, "ml-tenant", "u", false, 2.0).unwrap();
        let admitted = k.admission_cycle(&mut c, &s, 2.0);
        assert_eq!(admitted, vec![t4_wl]);
        // A whole A100 is 7 more units — blocked by the same grant.
        let whole = c.create_pod(PodSpec::batch(
            "u",
            Resources {
                gpus: 1,
                gpu_model: Some(GpuModel::A100),
                ..Resources::cpu_mem(1_000, GIB)
            },
            "train",
        ));
        k.submit(whole, "ml-tenant", "u", false, 3.0).unwrap();
        assert!(k.admission_cycle(&mut c, &s, 3.0).is_empty());
        assert_eq!(k.pending_count(), 2);
        k.check_cohort_invariants().unwrap();
        c.check_accounting().unwrap();
    }

    /// While an owner starves, stage 4 refuses to lend its cohort's
    /// headroom to new borrowers (no leapfrogging).
    #[test]
    fn starved_cohort_blocks_new_borrowing() {
        let (mut c, s, mut k) = farm();
        k.add_queue(
            ClusterQueue::with_nominal("owner", QuotaVec::cpu(6_000))
                .in_cohort("tenants"),
        );
        k.add_queue(
            ClusterQueue::with_nominal("borrower", QuotaVec::cpu(1_000))
                .in_cohort("tenants"),
        );
        // An owner pod within its CPU quota but physically unplaceable
        // (memory is not a quota dimension) keeps the owner permanently
        // starving: entitled, yet never admitted.
        let big_mem = c.create_pod(PodSpec::batch(
            "u",
            Resources::cpu_mem(2_000, 64 * GIB),
            "job",
        ));
        k.submit(big_mem, "owner", "u", false, 0.0).unwrap();
        // The borrower wants to borrow — and would succeed quota-wise.
        submit_batch(&mut c, &mut k, "borrower", 2_000);
        let admitted = k.admission_cycle(&mut c, &s, 1.0);
        assert!(
            admitted.is_empty(),
            "borrowing is frozen while the cohort owner starves"
        );
        assert_eq!(k.pending_count(), 2);
        k.check_cohort_invariants().unwrap();
    }

    /// The chaos recovery path: a drained node's workloads requeue at
    /// the front with quota released, a fault stamp, and a backoff
    /// deadline that admission cycles respect until it passes.
    #[test]
    fn fault_requeue_backs_off_on_the_admission_grid() {
        let (mut c, s, mut k) = farm();
        let w1 = submit_batch(&mut c, &mut k, "local-batch", 3_000);
        let w2 = submit_batch(&mut c, &mut k, "local-batch", 3_000);
        k.admission_cycle(&mut c, &s, 0.0);
        assert_eq!(c.running_pods(), 2);

        let victims = c.drain("n1").unwrap();
        assert_eq!(victims.len(), 2);
        let (requeued, exhausted) =
            k.requeue_faulted(&mut c, &victims, 10.0, 10.0, 5);
        assert_eq!(requeued, vec![w1, w2], "seniority order preserved");
        assert!(exhausted.is_empty());
        assert_eq!(k.pending_ids(), vec![w1, w2]);
        assert_eq!(k.n_fault_evictions, 2);
        let w = k.workload(w1).unwrap();
        assert_eq!(w.state, WorkloadState::Queued);
        assert_eq!(w.preempted_by, Some(PreemptReason::FaultEviction));
        assert_eq!(w.not_before, Some(20.0), "base backoff on first fault");
        assert_eq!(k.queue("local-batch").unwrap().used, QuotaVec::ZERO);
        k.respawn_evicted_pods(&mut c);

        // Before the deadline nothing admits; at/after it both do.
        assert!(k.admission_cycle(&mut c, &s, 15.0).is_empty());
        assert_eq!(k.next_not_before(15.0), Some(20.0));
        let admitted = k.admission_cycle(&mut c, &s, 20.0);
        assert_eq!(admitted, vec![w1, w2]);
        assert_eq!(k.n_fault_recoveries, 2);
        assert!((k.fault_recovery_max_s - 10.0).abs() < 1e-9);
        c.check_accounting().unwrap();
        k.check_cohort_invariants().unwrap();
    }

    /// Retry budgets are bounded: one fault past the budget turns the
    /// workload terminal-Failed with the reason stamped on its pod.
    #[test]
    fn fault_retry_budget_exhaustion_is_terminal() {
        let (mut c, s, mut k) = farm();
        let w = submit_batch(&mut c, &mut k, "local-batch", 2_000);
        let mut now = 0.0;
        for round in 0..3 {
            let admitted = k.admission_cycle(&mut c, &s, now);
            assert_eq!(admitted, vec![w], "round {round} readmits");
            let victims = c.drain("n1").unwrap();
            let (_, exhausted) =
                k.requeue_faulted(&mut c, &victims, now, 5.0, 2);
            k.respawn_evicted_pods(&mut c);
            if round < 2 {
                assert!(exhausted.is_empty());
                now = k.workload(w).unwrap().not_before.unwrap();
            } else {
                assert_eq!(exhausted, vec![w], "third fault breaks budget 2");
            }
        }
        let wl = k.workload(w).unwrap();
        assert_eq!(wl.state, WorkloadState::Failed);
        assert!(wl.finished_at.is_some());
        assert_eq!(k.n_retry_exhausted, 1);
        assert_eq!(k.pending_count(), 0, "no stuck Pending entry");
        let p = c.pod(wl.pod).unwrap();
        assert_eq!(p.phase, PodPhase::Evicted);
        assert_eq!(
            p.failure_reason.as_deref(),
            Some("fault retry budget exhausted")
        );
        assert_eq!(k.queue("local-batch").unwrap().used, QuotaVec::ZERO);
        c.check_accounting().unwrap();
    }
}
