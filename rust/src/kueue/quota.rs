//! The hierarchical quota model behind Kueue admission: a unified
//! resource vector ([`QuotaVec`]) and the cohort grouping
//! ([`Cohort`]) that lets `ClusterQueue`s lend idle nominal quota to
//! each other.
//!
//! The paper promises to share the Institute's accelerators "as
//! effectively as possible, ensuring the diversity of the Institute's
//! research activities is not compromised" — which the real platform
//! delivers through Kueue's cohort semantics, not through isolated
//! per-queue ceilings. The model here mirrors upstream Kueue:
//!
//! * every `ClusterQueue` owns a **nominal** quota (a [`QuotaVec`]);
//! * queues grouped into a [`Cohort`] may **borrow** idle nominal
//!   quota from their cohort peers, bounded by the borrower's
//!   `borrowing_limit` and each lender's `lending_limit`;
//! * a queue under its nominal quota whose cohort is exhausted by
//!   borrowers is entitled to **reclaim**: the admission pipeline
//!   evicts the most-junior borrowing workloads until the owner is
//!   restored (see `Kueue::admission_cycle` and
//!   [`crate::cluster::PreemptReason::ReclaimBorrowed`]).
//!
//! ## The cohort invariant
//!
//! For every cohort, component-wise over the quota dimensions:
//!
//! ```text
//!   Σ_queues borrowed(q)  ≤  Σ_queues lendable(q)
//!   borrowed(q) = max(0, used(q) − nominal(q))
//!   lendable(q) = min(lending_limit(q), max(0, nominal(q) − used(q)))
//! ```
//!
//! which implies `Σ used ≤ Σ nominal` (the cohort capacity) and is
//! checked after every admission decision (`Kueue`'s admission passes
//! only admit states that preserve it; `Kueue::check_cohort_invariants`
//! re-derives it from scratch for the property tests).

use std::collections::BTreeSet;

use crate::cluster::Resources;

/// Unified quota resource vector: CPU millicores and GPU devices —
/// the two dimensions the §2 farm actually rations. The struct is the
/// single place a new dimension (e.g. per-GPU-model quota, FPGA
/// devices) would be added: every arithmetic/comparison helper below
/// is component-wise, so extending the vector extends the whole
/// admission pipeline at once.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QuotaVec {
    pub cpu_m: u64,
    pub gpus: u64,
}

impl QuotaVec {
    pub const ZERO: QuotaVec = QuotaVec { cpu_m: 0, gpus: 0 };

    pub fn new(cpu_m: u64, gpus: u64) -> Self {
        QuotaVec { cpu_m, gpus }
    }

    /// CPU-only vector (the common batch shape).
    pub fn cpu(cpu_m: u64) -> Self {
        QuotaVec { cpu_m, gpus: 0 }
    }

    /// The quota footprint of a pod request.
    pub fn of(r: &Resources) -> Self {
        QuotaVec { cpu_m: r.cpu_m, gpus: r.gpus as u64 }
    }

    pub fn is_zero(self) -> bool {
        self == Self::ZERO
    }

    pub fn add(self, o: QuotaVec) -> QuotaVec {
        QuotaVec {
            cpu_m: self.cpu_m.saturating_add(o.cpu_m),
            gpus: self.gpus.saturating_add(o.gpus),
        }
    }

    pub fn saturating_sub(self, o: QuotaVec) -> QuotaVec {
        QuotaVec {
            cpu_m: self.cpu_m.saturating_sub(o.cpu_m),
            gpus: self.gpus.saturating_sub(o.gpus),
        }
    }

    pub fn min(self, o: QuotaVec) -> QuotaVec {
        QuotaVec {
            cpu_m: self.cpu_m.min(o.cpu_m),
            gpus: self.gpus.min(o.gpus),
        }
    }

    /// Component-wise `self ≤ limit`.
    pub fn fits_within(self, limit: QuotaVec) -> bool {
        self.cpu_m <= limit.cpu_m && self.gpus <= limit.gpus
    }

    /// Dominant-resource share of `self` against `capacity`: the
    /// largest per-dimension fraction, as an exact rational (zero-
    /// capacity dimensions are skipped). Drives the admission
    /// pipeline's candidate ordering — queues furthest below their
    /// fair share admit first.
    pub fn dominant_share(self, capacity: QuotaVec) -> Share {
        let mut best = Share::ZERO;
        for (used, cap) in
            [(self.cpu_m, capacity.cpu_m), (self.gpus, capacity.gpus)]
        {
            if cap == 0 {
                continue;
            }
            let s = Share { num: used, den: cap };
            if s > best {
                best = s;
            }
        }
        best
    }
}

/// An exact rational share `num/den` with a total order via u128
/// cross-multiplication — no f64 anywhere near an admission decision,
/// so the candidate order is bit-reproducible across placement and
/// loop modes. `den == 0` is the canonical zero share.
#[derive(Clone, Copy, Debug)]
pub struct Share {
    pub num: u64,
    pub den: u64,
}

impl Share {
    pub const ZERO: Share = Share { num: 0, den: 0 };

    fn value(self) -> (u64, u64) {
        if self.den == 0 {
            (0, 1)
        } else {
            (self.num, self.den)
        }
    }
}

impl PartialEq for Share {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Share {}
impl PartialOrd for Share {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Share {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        let (an, ad) = self.value();
        let (bn, bd) = other.value();
        // a/b vs c/d  ⇔  a·d vs c·b (denominators positive).
        (an as u128 * bd as u128).cmp(&(bn as u128 * ad as u128))
    }
}

/// A cohort node in the quota tree: the named group of `ClusterQueue`s
/// whose idle nominal quota is mutually borrowable. The cohort itself
/// owns no quota — its capacity is the sum of its members' nominal
/// quotas (opportunistic members, which have no nominal quota, take no
/// part in the cohort math at all).
#[derive(Clone, Debug, Default)]
pub struct Cohort {
    pub name: String,
    members: BTreeSet<String>,
}

impl Cohort {
    pub fn new(name: &str) -> Self {
        Cohort { name: name.to_string(), members: BTreeSet::new() }
    }

    pub(crate) fn add_member(&mut self, queue: &str) {
        self.members.insert(queue.to_string());
    }

    /// Member queue names in deterministic (lexicographic) order.
    pub fn members(&self) -> impl Iterator<Item = &str> {
        self.members.iter().map(|s| s.as_str())
    }

    pub fn contains(&self, queue: &str) -> bool {
        self.members.contains(queue)
    }
}

/// A point-in-time aggregate over one cohort — the admission
/// pipeline's "snapshot cohort usage" stage, also exported to the
/// monitoring scrape.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CohortUsage {
    /// Σ member nominal quotas (the cohort capacity).
    pub capacity: QuotaVec,
    /// Σ member admitted local usage.
    pub used: QuotaVec,
    /// Σ member borrowed amounts (usage above nominal).
    pub borrowed: QuotaVec,
    /// Σ member lendable headroom (idle nominal, capped by each
    /// member's lending limit).
    pub lendable: QuotaVec,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quota_vec_componentwise_arithmetic() {
        let a = QuotaVec::new(4_000, 2);
        let b = QuotaVec::new(1_500, 3);
        assert_eq!(a.add(b), QuotaVec::new(5_500, 5));
        assert_eq!(a.saturating_sub(b), QuotaVec::new(2_500, 0));
        assert_eq!(a.min(b), QuotaVec::new(1_500, 2));
        assert!(QuotaVec::cpu(1_000).fits_within(a));
        assert!(!b.fits_within(a), "gpu dimension exceeds");
        assert!(QuotaVec::ZERO.is_zero());
    }

    #[test]
    fn quota_vec_of_resources_maps_dimensions() {
        let r = Resources { gpus: 2, ..Resources::cpu_mem(3_000, 1 << 30) };
        assert_eq!(QuotaVec::of(&r), QuotaVec::new(3_000, 2));
    }

    #[test]
    fn share_orders_exactly_without_floats() {
        // 1/3 < 2/5 < 1/2; equal fractions in different terms compare
        // Equal; the zero share is below everything positive.
        let third = Share { num: 1, den: 3 };
        let two_fifths = Share { num: 2, den: 5 };
        let half = Share { num: 3, den: 6 };
        assert!(third < two_fifths && two_fifths < half);
        assert_eq!(half, Share { num: 1, den: 2 });
        assert!(Share::ZERO < third);
        assert_eq!(Share::ZERO, Share { num: 0, den: 7 });
        // Cross-multiplication survives magnitudes that overflow u64.
        let big = Share { num: u64::MAX - 1, den: u64::MAX };
        let one = Share { num: u64::MAX, den: u64::MAX };
        assert!(big < one);
    }

    #[test]
    fn dominant_share_picks_the_scarcest_dimension() {
        let cap = QuotaVec::new(10_000, 4);
        // CPU at 20%, GPU at 50% → GPU dominates.
        let used = QuotaVec::new(2_000, 2);
        assert_eq!(used.dominant_share(cap), Share { num: 2, den: 4 });
        // Zero-capacity dimensions are skipped, not divided by.
        let cpu_only_cap = QuotaVec::cpu(10_000);
        let s = QuotaVec::new(5_000, 3).dominant_share(cpu_only_cap);
        assert_eq!(s, Share { num: 5_000, den: 10_000 });
        assert_eq!(QuotaVec::ZERO.dominant_share(cap), Share::ZERO);
    }

    #[test]
    fn cohort_membership_is_deterministic() {
        let mut c = Cohort::new("tenants");
        c.add_member("zeta");
        c.add_member("alpha");
        c.add_member("zeta");
        let members: Vec<&str> = c.members().collect();
        assert_eq!(members, vec!["alpha", "zeta"]);
        assert!(c.contains("alpha") && !c.contains("beta"));
    }
}
