//! The hierarchical quota model behind Kueue admission: a unified
//! resource vector ([`QuotaVec`]) and the cohort grouping
//! ([`Cohort`]) that lets `ClusterQueue`s lend idle nominal quota to
//! each other.
//!
//! The paper promises to share the Institute's accelerators "as
//! effectively as possible, ensuring the diversity of the Institute's
//! research activities is not compromised" — which the real platform
//! delivers through Kueue's cohort semantics, not through isolated
//! per-queue ceilings. The model here mirrors upstream Kueue:
//!
//! * every `ClusterQueue` owns a **nominal** quota (a [`QuotaVec`]);
//! * queues grouped into a [`Cohort`] may **borrow** idle nominal
//!   quota from their cohort peers, bounded by the borrower's
//!   `borrowing_limit` and each lender's `lending_limit`;
//! * a queue under its nominal quota whose cohort is exhausted by
//!   borrowers is entitled to **reclaim**: the admission pipeline
//!   evicts the most-junior borrowing workloads until the owner is
//!   restored (see `Kueue::admission_cycle` and
//!   [`crate::cluster::PreemptReason::ReclaimBorrowed`]).
//!
//! ## The quota dimensions
//!
//! [`QuotaVec`] rations CPU millicores, whole GPU devices
//! (model-agnostic) — and, since the GPU partitioning subsystem, a
//! **per-GPU-model slice-weighted dimension**: compute units where a
//! whole device of model `m` is worth `m.compute_units()` units and a
//! carved partition is worth its profile's units (an A100 1g.5gb
//! slice = 1 of 7). This is what lets a cohort ration
//! "A100-equivalents" separately from T4s: the T4 tenant exhausting
//! its time-slice replicas cannot starve the A100 MIG pool. The
//! mapping from a pod request ([`QuotaVec::of`]):
//!
//! * CPU → `cpu_m`, always;
//! * `n` whole devices, model-agnostic → `gpus += n` only (no model
//!   to attribute them to);
//! * `n` whole devices of model `m` → `gpus += n` AND
//!   `gpu_units[m] += n · m.compute_units()`;
//! * one slice of `(m, profile)` → `gpu_units[m] += profile.units()`
//!   only — fractional usage never consumes the whole-device
//!   dimension.
//!
//! A nominal quota therefore grants a per-model dimension only if it
//! sets it (`with_gpu_units` / `with_whole_gpus`): zero entitlement on
//! a dimension means zero, exactly like the seed's CPU-only quotas
//! blocking GPU jobs. Every arithmetic/comparison helper below is
//! component-wise over all `2 + GpuModel::COUNT` dimensions, so the
//! whole admission pipeline (shares, borrow/lend, reclaim deficits)
//! extends at once.
//!
//! ## The cohort invariant
//!
//! For every cohort, component-wise over the quota dimensions:
//!
//! ```text
//!   Σ_queues borrowed(q)  ≤  Σ_queues lendable(q)
//!   borrowed(q) = max(0, used(q) − nominal(q))
//!   lendable(q) = min(lending_limit(q), max(0, nominal(q) − used(q)))
//! ```
//!
//! which implies `Σ used ≤ Σ nominal` (the cohort capacity) and is
//! checked after every admission decision (`Kueue`'s admission passes
//! only admit states that preserve it; `Kueue::check_cohort_invariants`
//! re-derives it from scratch for the property tests).

use std::collections::BTreeSet;

use crate::cluster::{GpuModel, Resources};

/// Unified quota resource vector: CPU millicores, whole GPU devices,
/// and per-GPU-model slice-weighted compute units (see the module
/// docs for the request mapping).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QuotaVec {
    pub cpu_m: u64,
    /// Whole devices, any model.
    pub gpus: u64,
    /// Slice-weighted compute units per GPU model, indexed by
    /// [`GpuModel::index`] (a whole device = `compute_units()` units).
    pub gpu_units: [u64; GpuModel::COUNT],
}

impl QuotaVec {
    pub const ZERO: QuotaVec =
        QuotaVec { cpu_m: 0, gpus: 0, gpu_units: [0; GpuModel::COUNT] };

    /// Unbounded in every dimension (the no-borrowing-limit ceiling).
    pub const MAX: QuotaVec = QuotaVec {
        cpu_m: u64::MAX,
        gpus: u64::MAX,
        gpu_units: [u64::MAX; GpuModel::COUNT],
    };

    /// CPU plus *model-agnostic* whole devices. The per-model unit
    /// dimensions stay zero, so a grant built this way admits only
    /// requests that leave `gpu_model`/`gpu_slice` unset — the §2
    /// hub flavors are model-typed, so GPU grants for those belong to
    /// [`QuotaVec::with_whole_gpus`] / [`QuotaVec::with_gpu_units`].
    pub fn new(cpu_m: u64, gpus: u64) -> Self {
        QuotaVec { cpu_m, gpus, ..Self::ZERO }
    }

    /// CPU-only vector (the common batch shape).
    pub fn cpu(cpu_m: u64) -> Self {
        QuotaVec { cpu_m, ..Self::ZERO }
    }

    /// Builder: grant `units` more slice-weighted compute units of
    /// `model` (an A100 1g.5gb slice costs 1; a whole A100 costs 7).
    /// Accumulates, like [`QuotaVec::with_whole_gpus`], so chaining
    /// the two on one model never discards an entitlement.
    pub fn with_gpu_units(mut self, model: GpuModel, units: u64) -> Self {
        self.gpu_units[model.index()] =
            self.gpu_units[model.index()].saturating_add(units);
        self
    }

    /// Builder: grant `n` whole devices of `model` — both the
    /// whole-device dimension and the model's unit dimension, so the
    /// quota admits the devices whichever way they are consumed
    /// (whole or carved).
    pub fn with_whole_gpus(mut self, model: GpuModel, n: u64) -> Self {
        self.gpus = self.gpus.saturating_add(n);
        self.gpu_units[model.index()] = self.gpu_units[model.index()]
            .saturating_add(n.saturating_mul(model.compute_units() as u64));
        self
    }

    /// The quota footprint of a pod request (see the module docs).
    pub fn of(r: &Resources) -> Self {
        let mut v = QuotaVec {
            cpu_m: r.cpu_m,
            gpus: r.gpus as u64,
            gpu_units: [0; GpuModel::COUNT],
        };
        if r.gpus > 0 {
            if let Some(m) = r.gpu_model {
                v.gpu_units[m.index()] =
                    r.gpus as u64 * m.compute_units() as u64;
            }
        }
        if let Some(sr) = r.gpu_slice {
            v.gpu_units[sr.model.index()] = sr.profile.units() as u64;
        }
        v
    }

    pub fn is_zero(self) -> bool {
        self == Self::ZERO
    }

    pub fn add(self, o: QuotaVec) -> QuotaVec {
        let mut gpu_units = [0u64; GpuModel::COUNT];
        for (i, u) in gpu_units.iter_mut().enumerate() {
            *u = self.gpu_units[i].saturating_add(o.gpu_units[i]);
        }
        QuotaVec {
            cpu_m: self.cpu_m.saturating_add(o.cpu_m),
            gpus: self.gpus.saturating_add(o.gpus),
            gpu_units,
        }
    }

    pub fn saturating_sub(self, o: QuotaVec) -> QuotaVec {
        let mut gpu_units = [0u64; GpuModel::COUNT];
        for (i, u) in gpu_units.iter_mut().enumerate() {
            *u = self.gpu_units[i].saturating_sub(o.gpu_units[i]);
        }
        QuotaVec {
            cpu_m: self.cpu_m.saturating_sub(o.cpu_m),
            gpus: self.gpus.saturating_sub(o.gpus),
            gpu_units,
        }
    }

    pub fn min(self, o: QuotaVec) -> QuotaVec {
        let mut gpu_units = [0u64; GpuModel::COUNT];
        for (i, u) in gpu_units.iter_mut().enumerate() {
            *u = self.gpu_units[i].min(o.gpu_units[i]);
        }
        QuotaVec {
            cpu_m: self.cpu_m.min(o.cpu_m),
            gpus: self.gpus.min(o.gpus),
            gpu_units,
        }
    }

    /// Component-wise `self ≤ limit`.
    pub fn fits_within(self, limit: QuotaVec) -> bool {
        self.cpu_m <= limit.cpu_m
            && self.gpus <= limit.gpus
            && self
                .gpu_units
                .iter()
                .zip(limit.gpu_units.iter())
                .all(|(a, b)| a <= b)
    }

    /// `(used, capacity)` pairs over every dimension, in a fixed
    /// deterministic order (CPU, whole GPUs, then per-model units).
    fn dims(self, capacity: QuotaVec) -> impl Iterator<Item = (u64, u64)> {
        [(self.cpu_m, capacity.cpu_m), (self.gpus, capacity.gpus)]
            .into_iter()
            .chain(
                self.gpu_units
                    .into_iter()
                    .zip(capacity.gpu_units),
            )
    }

    /// Dominant-resource share of `self` against `capacity`: the
    /// largest per-dimension fraction, as an exact rational (zero-
    /// capacity dimensions are skipped). Drives the admission
    /// pipeline's candidate ordering — queues furthest below their
    /// fair share admit first.
    pub fn dominant_share(self, capacity: QuotaVec) -> Share {
        let mut best = Share::ZERO;
        for (used, cap) in self.dims(capacity) {
            if cap == 0 {
                continue;
            }
            let s = Share { num: used, den: cap };
            if s > best {
                best = s;
            }
        }
        best
    }

    /// Do the two vectors share a non-zero dimension? Gates reclaim
    /// victim eligibility: evicting a CPU-only workload cannot repay a
    /// GPU debt, and evicting a T4 time-slice borrower cannot repay an
    /// A100-unit deficit.
    pub fn overlaps(self, o: QuotaVec) -> bool {
        (self.cpu_m > 0 && o.cpu_m > 0)
            || (self.gpus > 0 && o.gpus > 0)
            || self
                .gpu_units
                .iter()
                .zip(o.gpu_units.iter())
                .any(|(&a, &b)| a > 0 && b > 0)
    }
}

/// An exact rational share `num/den` with a total order via u128
/// cross-multiplication — no f64 anywhere near an admission decision,
/// so the candidate order is bit-reproducible across placement and
/// loop modes. `den == 0` is the canonical zero share.
#[derive(Clone, Copy, Debug)]
pub struct Share {
    pub num: u64,
    pub den: u64,
}

impl Share {
    pub const ZERO: Share = Share { num: 0, den: 0 };

    fn value(self) -> (u64, u64) {
        if self.den == 0 {
            (0, 1)
        } else {
            (self.num, self.den)
        }
    }
}

impl PartialEq for Share {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Share {}
impl PartialOrd for Share {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Share {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        let (an, ad) = self.value();
        let (bn, bd) = other.value();
        // a/b vs c/d  ⇔  a·d vs c·b (denominators positive).
        (an as u128 * bd as u128).cmp(&(bn as u128 * ad as u128))
    }
}

/// A cohort node in the quota tree: the named group of `ClusterQueue`s
/// whose idle nominal quota is mutually borrowable. The cohort itself
/// owns no quota — its capacity is the sum of its members' nominal
/// quotas (opportunistic members, which have no nominal quota, take no
/// part in the cohort math at all).
#[derive(Clone, Debug, Default)]
pub struct Cohort {
    pub name: String,
    members: BTreeSet<String>,
}

impl Cohort {
    pub fn new(name: &str) -> Self {
        Cohort { name: name.to_string(), members: BTreeSet::new() }
    }

    pub(crate) fn add_member(&mut self, queue: &str) {
        self.members.insert(queue.to_string());
    }

    /// Member queue names in deterministic (lexicographic) order.
    pub fn members(&self) -> impl Iterator<Item = &str> {
        self.members.iter().map(|s| s.as_str())
    }

    pub fn contains(&self, queue: &str) -> bool {
        self.members.contains(queue)
    }
}

/// A point-in-time aggregate over one cohort — the admission
/// pipeline's "snapshot cohort usage" stage, also exported to the
/// monitoring scrape.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CohortUsage {
    /// Σ member nominal quotas (the cohort capacity).
    pub capacity: QuotaVec,
    /// Σ member admitted local usage.
    pub used: QuotaVec,
    /// Σ member borrowed amounts (usage above nominal).
    pub borrowed: QuotaVec,
    /// Σ member lendable headroom (idle nominal, capped by each
    /// member's lending limit).
    pub lendable: QuotaVec,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::gpu::SliceProfile;

    #[test]
    fn quota_vec_componentwise_arithmetic() {
        let a = QuotaVec::new(4_000, 2);
        let b = QuotaVec::new(1_500, 3);
        assert_eq!(a.add(b), QuotaVec::new(5_500, 5));
        assert_eq!(a.saturating_sub(b), QuotaVec::new(2_500, 0));
        assert_eq!(a.min(b), QuotaVec::new(1_500, 2));
        assert!(QuotaVec::cpu(1_000).fits_within(a));
        assert!(!b.fits_within(a), "gpu dimension exceeds");
        assert!(QuotaVec::ZERO.is_zero());
        assert!(a.fits_within(QuotaVec::MAX));
    }

    #[test]
    fn quota_vec_of_resources_maps_dimensions() {
        let r = Resources { gpus: 2, ..Resources::cpu_mem(3_000, 1 << 30) };
        assert_eq!(QuotaVec::of(&r), QuotaVec::new(3_000, 2));
    }

    #[test]
    fn model_constrained_whole_devices_charge_unit_dimension() {
        let r = Resources {
            gpus: 2,
            gpu_model: Some(GpuModel::A100),
            ..Resources::cpu_mem(1_000, 1 << 30)
        };
        let v = QuotaVec::of(&r);
        assert_eq!(v.gpus, 2);
        assert_eq!(v.gpu_units[GpuModel::A100.index()], 14, "2 × 7 units");
        assert_eq!(v.gpu_units[GpuModel::TeslaT4.index()], 0);
        // The matching grant admits it either way.
        let grant = QuotaVec::cpu(8_000).with_whole_gpus(GpuModel::A100, 2);
        assert!(v.fits_within(grant));
        // A units-only grant does not cover whole devices…
        let units_only =
            QuotaVec::cpu(8_000).with_gpu_units(GpuModel::A100, 14);
        assert!(!v.fits_within(units_only));
    }

    #[test]
    fn slices_charge_only_their_model_units() {
        let r = Resources::notebook_gpu_slice(
            GpuModel::A100,
            SliceProfile::Mig2g10gb,
        );
        let v = QuotaVec::of(&r);
        assert_eq!(v.gpus, 0, "fractional usage spares the whole-GPU dim");
        assert_eq!(v.gpu_units[GpuModel::A100.index()], 2);
        // Seven 1g slices fit an exactly-one-A100 units grant; an
        // eighth does not.
        let one_a100 = QuotaVec::cpu(100_000)
            .with_gpu_units(GpuModel::A100, 7);
        let slice = QuotaVec::of(&Resources::notebook_gpu_slice(
            GpuModel::A100,
            SliceProfile::Mig1g5gb,
        ));
        let mut used = QuotaVec::ZERO;
        for _ in 0..7 {
            used = used.add(slice);
        }
        assert!(used.fits_within(one_a100));
        assert!(!used.add(slice).fits_within(one_a100));
        // And the T4 dimension is rationed independently.
        let t4 = QuotaVec::of(&Resources::notebook_gpu_slice(
            GpuModel::TeslaT4,
            SliceProfile::TsQuarter,
        ));
        assert!(!used.add(t4).fits_within(one_a100));
        assert!(used
            .add(t4)
            .fits_within(one_a100.with_gpu_units(GpuModel::TeslaT4, 1)));
    }

    #[test]
    fn share_orders_exactly_without_floats() {
        // 1/3 < 2/5 < 1/2; equal fractions in different terms compare
        // Equal; the zero share is below everything positive.
        let third = Share { num: 1, den: 3 };
        let two_fifths = Share { num: 2, den: 5 };
        let half = Share { num: 3, den: 6 };
        assert!(third < two_fifths && two_fifths < half);
        assert_eq!(half, Share { num: 1, den: 2 });
        assert!(Share::ZERO < third);
        assert_eq!(Share::ZERO, Share { num: 0, den: 7 });
        // Cross-multiplication survives magnitudes that overflow u64.
        let big = Share { num: u64::MAX - 1, den: u64::MAX };
        let one = Share { num: u64::MAX, den: u64::MAX };
        assert!(big < one);
    }

    #[test]
    fn dominant_share_picks_the_scarcest_dimension() {
        let cap = QuotaVec::new(10_000, 4);
        // CPU at 20%, GPU at 50% → GPU dominates.
        let used = QuotaVec::new(2_000, 2);
        assert_eq!(used.dominant_share(cap), Share { num: 2, den: 4 });
        // Zero-capacity dimensions are skipped, not divided by.
        let cpu_only_cap = QuotaVec::cpu(10_000);
        let s = QuotaVec::new(5_000, 3).dominant_share(cpu_only_cap);
        assert_eq!(s, Share { num: 5_000, den: 10_000 });
        assert_eq!(QuotaVec::ZERO.dominant_share(cap), Share::ZERO);
        // Per-model unit dimensions participate: 6/7 A100 units beats
        // 1/2 CPU.
        let cap = QuotaVec::cpu(10_000).with_gpu_units(GpuModel::A100, 7);
        let used =
            QuotaVec::cpu(5_000).with_gpu_units(GpuModel::A100, 6);
        assert_eq!(used.dominant_share(cap), Share { num: 6, den: 7 });
    }

    #[test]
    fn unit_builders_accumulate_order_independently() {
        let a = QuotaVec::cpu(1_000)
            .with_whole_gpus(GpuModel::A100, 1)
            .with_gpu_units(GpuModel::A100, 2);
        let b = QuotaVec::cpu(1_000)
            .with_gpu_units(GpuModel::A100, 2)
            .with_whole_gpus(GpuModel::A100, 1);
        assert_eq!(a, b);
        assert_eq!(a.gpus, 1);
        assert_eq!(a.gpu_units[GpuModel::A100.index()], 9, "7 + 2 units");
        // The whole device stays admissible under its own grant.
        let whole = QuotaVec::of(&Resources {
            gpus: 1,
            gpu_model: Some(GpuModel::A100),
            ..Resources::cpu_mem(500, 1 << 30)
        });
        assert!(whole.fits_within(a));
    }

    #[test]
    fn overlaps_requires_a_shared_nonzero_dimension() {
        let cpu = QuotaVec::cpu(1_000);
        let a100 = QuotaVec::ZERO.with_gpu_units(GpuModel::A100, 1);
        let t4 = QuotaVec::ZERO.with_gpu_units(GpuModel::TeslaT4, 1);
        assert!(cpu.overlaps(QuotaVec::cpu(5)));
        assert!(!cpu.overlaps(a100));
        assert!(!a100.overlaps(t4), "different models never overlap");
        assert!(a100.overlaps(a100));
        assert!(QuotaVec::new(0, 1).overlaps(QuotaVec::new(0, 3)));
    }

    #[test]
    fn cohort_membership_is_deterministic() {
        let mut c = Cohort::new("tenants");
        c.add_member("zeta");
        c.add_member("alpha");
        c.add_member("zeta");
        let members: Vec<&str> = c.members().collect();
        assert_eq!(members, vec!["alpha", "zeta"]);
        assert!(c.contains("alpha") && !c.contains("beta"));
    }
}
