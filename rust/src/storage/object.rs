//! Centralised object storage (§3) and the patched-rclone mount.
//!
//! "Large datasets must be stored in a centralized object storage
//! service based on Rados Gateway and centrally managed by DataCloud. To
//! ease accessing the datasets ... a patched version of rclone was
//! developed to enable mounting the user's bucket in the JupyterLab
//! instance using the same authentication token used to access
//! JupyterHub. The mount operation is automated at spawn time."
//!
//! The store is bucket/key → object with token-scoped access (each user
//! bucket is readable/writable only by its owner unless a bucket policy
//! grants a group). [`RcloneMount`] is the POSIX facade with FUSE-level
//! performance (the §3 bandwidth-limitation caveat).

use std::collections::BTreeMap;

use crate::iam::{AuthError, Iam, Token};

use super::vfs::Content;
use super::{Cost, PerfModel};

#[derive(Clone, Debug)]
pub struct Object {
    pub content: Content,
    pub etag: u64,
    pub mtime: f64,
}

#[derive(Clone, Debug, Default)]
pub struct Bucket {
    pub owner: String,
    /// Groups granted read access by bucket policy.
    pub read_groups: Vec<String>,
    objects: BTreeMap<String, Object>,
}

#[derive(Debug)]
pub struct ObjectStore {
    buckets: BTreeMap<String, Bucket>,
    perf: PerfModel,
    /// Lifetime op counters (monitoring exporter feeds on these).
    pub n_puts: u64,
    pub n_gets: u64,
}

fn etag_of(content: &Content) -> u64 {
    // Cheap stable etag: fingerprint of first/last 64 bytes + length.
    let head = content.bytes(0, 64);
    let tail_off = content.len().saturating_sub(64);
    let tail = content.bytes(tail_off, 64);
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in head.iter().chain(tail.iter()) {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h ^ content.len()
}

impl ObjectStore {
    pub fn new() -> Self {
        ObjectStore {
            buckets: BTreeMap::new(),
            perf: PerfModel::object_store(),
            n_puts: 0,
            n_gets: 0,
        }
    }

    pub fn create_bucket(&mut self, name: &str, owner: &str) -> Result<(), String> {
        if self.buckets.contains_key(name) {
            return Err(format!("bucket {name} exists"));
        }
        self.buckets.insert(
            name.to_string(),
            Bucket { owner: owner.to_string(), ..Default::default() },
        );
        Ok(())
    }

    pub fn grant_group(&mut self, bucket: &str, group: &str) -> Result<(), String> {
        self.buckets
            .get_mut(bucket)
            .ok_or_else(|| format!("no bucket {bucket}"))?
            .read_groups
            .push(group.to_string());
        Ok(())
    }

    fn authorise<'a>(
        &'a self,
        iam: &Iam,
        token: &Token,
        bucket: &str,
        write: bool,
        now: f64,
    ) -> Result<&'a Bucket, String> {
        let user = iam
            .validate(token, now)
            .map_err(|e: AuthError| format!("auth failed: {e:?}"))?;
        let b = self
            .buckets
            .get(bucket)
            .ok_or_else(|| format!("no bucket {bucket}"))?;
        if b.owner == user.subject {
            return Ok(b);
        }
        if !write
            && b.read_groups.iter().any(|g| user.groups.contains(g))
        {
            return Ok(b);
        }
        Err(format!(
            "access denied to bucket {bucket} for {}",
            user.subject
        ))
    }

    pub fn put(
        &mut self,
        iam: &Iam,
        token: &Token,
        bucket: &str,
        key: &str,
        content: Content,
        now: f64,
    ) -> Result<Cost, String> {
        self.authorise(iam, token, bucket, true, now)?;
        let bytes = content.len();
        let etag = etag_of(&content);
        self.buckets
            .get_mut(bucket)
            .unwrap()
            .objects
            .insert(key.to_string(), Object { content, etag, mtime: now });
        self.n_puts += 1;
        let mut c = self.perf.write_cost(bytes);
        c.add(self.perf.meta_cost(1));
        Ok(c)
    }

    pub fn get(
        &mut self,
        iam: &Iam,
        token: &Token,
        bucket: &str,
        key: &str,
        now: f64,
    ) -> Result<(Content, Cost), String> {
        let b = self.authorise(iam, token, bucket, false, now)?;
        let obj = b
            .objects
            .get(key)
            .ok_or_else(|| format!("no object {bucket}/{key}"))?;
        let content = obj.content.clone();
        self.n_gets += 1;
        let mut c = self.perf.read_cost(content.len());
        c.add(self.perf.meta_cost(1));
        Ok((content, c))
    }

    pub fn list(
        &self,
        iam: &Iam,
        token: &Token,
        bucket: &str,
        now: f64,
    ) -> Result<(Vec<String>, Cost), String> {
        let b = self.authorise(iam, token, bucket, false, now)?;
        let keys: Vec<String> = b.objects.keys().cloned().collect();
        let cost = self.perf.meta_cost(1 + keys.len() as u64 / 1000);
        Ok((keys, cost))
    }

    /// Unauthenticated internal access (JuiceFS data plane, backup
    /// target) — platform services hold the bucket credentials directly.
    pub fn service_put(
        &mut self,
        bucket: &str,
        key: &str,
        content: Content,
        now: f64,
    ) -> Result<Cost, String> {
        if !self.buckets.contains_key(bucket) {
            return Err(format!("no bucket {bucket}"));
        }
        let bytes = content.len();
        let etag = etag_of(&content);
        self.buckets
            .get_mut(bucket)
            .unwrap()
            .objects
            .insert(key.to_string(), Object { content, etag, mtime: now });
        self.n_puts += 1;
        let mut c = self.perf.write_cost(bytes);
        c.add(self.perf.meta_cost(1));
        Ok(c)
    }

    pub fn service_get(
        &mut self,
        bucket: &str,
        key: &str,
    ) -> Result<(Content, Cost), String> {
        let obj = self
            .buckets
            .get(bucket)
            .ok_or_else(|| format!("no bucket {bucket}"))?
            .objects
            .get(key)
            .ok_or_else(|| format!("no object {bucket}/{key}"))?;
        let content = obj.content.clone();
        self.n_gets += 1;
        let mut c = self.perf.read_cost(content.len());
        c.add(self.perf.meta_cost(1));
        Ok((content, c))
    }

    pub fn object_count(&self, bucket: &str) -> usize {
        self.buckets.get(bucket).map(|b| b.objects.len()).unwrap_or(0)
    }

    pub fn bucket_bytes(&self, bucket: &str) -> u64 {
        self.buckets
            .get(bucket)
            .map(|b| b.objects.values().map(|o| o.content.len()).sum())
            .unwrap_or(0)
    }
}

impl Default for ObjectStore {
    fn default() -> Self {
        Self::new()
    }
}

/// The patched-rclone FUSE mount: POSIX reads over a bucket, charged at
/// FUSE-over-HTTP performance. Mounted automatically at spawn time with
/// the hub token.
#[derive(Debug)]
pub struct RcloneMount {
    pub bucket: String,
    pub token: Token,
    perf: PerfModel,
    pub mounted: bool,
}

impl RcloneMount {
    /// Mount at spawn: one auth round-trip + FUSE setup.
    pub fn mount(bucket: &str, token: Token) -> (Self, Cost) {
        let m = RcloneMount {
            bucket: bucket.to_string(),
            token,
            perf: PerfModel::rclone_mount(),
            mounted: true,
        };
        let cost = Cost { seconds: 0.8, bytes_moved: 0, meta_ops: 3 };
        (m, cost)
    }

    pub fn unmount(&mut self) {
        self.mounted = false;
    }

    /// POSIX-style read through the mount.
    pub fn read(
        &self,
        store: &mut ObjectStore,
        iam: &Iam,
        key: &str,
        now: f64,
    ) -> Result<(u64, Cost), String> {
        if !self.mounted {
            return Err("mount is not active".into());
        }
        let (content, _) = store.get(iam, &self.token, &self.bucket, key, now)?;
        let bytes = content.len();
        let mut c = self.perf.read_cost(bytes);
        c.add(self.perf.meta_cost(1));
        Ok((bytes, c))
    }

    /// Sequential scan of the whole bucket (one training epoch through
    /// the mount — the slow path of STO1).
    pub fn scan(
        &self,
        store: &mut ObjectStore,
        iam: &Iam,
        now: f64,
    ) -> Result<(u64, Cost), String> {
        let (keys, list_cost) = store.list(iam, &self.token, &self.bucket, now)?;
        let mut total = list_cost;
        let mut bytes = 0;
        for k in keys {
            let (b, c) = self.read(store, iam, &k, now)?;
            bytes += b;
            total.add(c);
        }
        Ok((bytes, total))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MIB;

    fn setup() -> (ObjectStore, Iam, Token, Token) {
        let mut iam = Iam::new(1);
        iam.register("rosa", "Rosa", &["lhcb-flashsim"]);
        iam.register("diego", "Diego", &["cms-ml-trigger"]);
        let rosa = iam.issue_token("rosa", 0.0).unwrap();
        let diego = iam.issue_token("diego", 0.0).unwrap();
        let mut store = ObjectStore::new();
        store.create_bucket("rosa-data", "rosa").unwrap();
        (store, iam, rosa, diego)
    }

    #[test]
    fn owner_can_put_and_get() {
        let (mut store, iam, rosa, _) = setup();
        store
            .put(&iam, &rosa, "rosa-data", "ds/x.bin",
                 Content::Synthetic { size: MIB, seed: 3 }, 1.0)
            .unwrap();
        let (content, cost) =
            store.get(&iam, &rosa, "rosa-data", "ds/x.bin", 2.0).unwrap();
        assert_eq!(content.len(), MIB);
        assert!(cost.seconds > 0.0);
    }

    #[test]
    fn foreign_user_denied_until_group_grant() {
        let (mut store, iam, rosa, diego) = setup();
        store
            .put(&iam, &rosa, "rosa-data", "x",
                 Content::Real(vec![1]), 1.0)
            .unwrap();
        assert!(store.get(&iam, &diego, "rosa-data", "x", 2.0).is_err());
        store.grant_group("rosa-data", "cms-ml-trigger").unwrap();
        assert!(store.get(&iam, &diego, "rosa-data", "x", 3.0).is_ok());
        // …but still no write access.
        assert!(store
            .put(&iam, &diego, "rosa-data", "y", Content::Real(vec![2]), 4.0)
            .is_err());
    }

    #[test]
    fn expired_token_rejected() {
        let (mut store, iam, rosa, _) = setup();
        let late = (rosa.expires_at + 10) as f64;
        assert!(store
            .put(&iam, &rosa, "rosa-data", "x", Content::Real(vec![1]), late)
            .is_err());
    }

    #[test]
    fn etag_changes_with_content() {
        let a = etag_of(&Content::Real(b"hello".to_vec()));
        let b = etag_of(&Content::Real(b"world".to_vec()));
        assert_ne!(a, b);
        let c = etag_of(&Content::Synthetic { size: 100, seed: 1 });
        let d = etag_of(&Content::Synthetic { size: 100, seed: 1 });
        assert_eq!(c, d);
    }

    #[test]
    fn rclone_mount_scan_slower_than_direct() {
        let (mut store, iam, rosa, _) = setup();
        for i in 0..20 {
            store
                .put(&iam, &rosa, "rosa-data", &format!("shard-{i}"),
                     Content::Synthetic { size: 10 * MIB, seed: i }, 0.0)
                .unwrap();
        }
        let (mount, mount_cost) = RcloneMount::mount("rosa-data", rosa.clone());
        assert!(mount_cost.seconds > 0.0);
        let (bytes, through_mount) = mount.scan(&mut store, &iam, 1.0).unwrap();
        assert_eq!(bytes, 200 * MIB);
        // direct S3 gets for comparison
        let mut direct = Cost::zero();
        for i in 0..20 {
            let (_, c) = store
                .get(&iam, &rosa, "rosa-data", &format!("shard-{i}"), 1.0)
                .unwrap();
            direct.add(c);
        }
        assert!(through_mount.seconds > direct.seconds);
    }

    #[test]
    fn unmounted_read_fails() {
        let (mut store, iam, rosa, _) = setup();
        store
            .put(&iam, &rosa, "rosa-data", "x", Content::Real(vec![1]), 0.0)
            .unwrap();
        let (mut mount, _) = RcloneMount::mount("rosa-data", rosa);
        mount.unmount();
        assert!(mount.read(&mut store, &iam, "x", 1.0).is_err());
    }
}
