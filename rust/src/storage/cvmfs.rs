//! CVMFS-like software distribution file system (§3).
//!
//! "A more effective and popular alternative to installing packages in
//! the container is to rely on the binaries distributed through the CERN
//! VM file system (cvmfs). CVMFS ... is made available to the platform
//! users through a Kubernetes installation that shares the caches among
//! different users and sessions."
//!
//! Model: a read-only, content-addressed repository published centrally
//! (Stratum-0), accessed through a *shared per-cluster cache*. First
//! access to an object pays the WAN fetch; subsequent accesses from any
//! session on the same cluster hit the cache at NVMe speed — that
//! cache-sharing is the §3 point, and it is measurable (hit ratio is
//! exported to monitoring).

use sha2::{Digest, Sha256};
use std::collections::{BTreeMap, BTreeSet};

use super::vfs::Content;
use super::{Cost, PerfModel};

fn content_hash(c: &Content) -> [u8; 32] {
    // Sampled content address: length + head + tail + strided windows.
    // Hashing whole multi-GiB (synthetic) images would dominate test
    // time without changing dedup semantics — synthetic streams are
    // fully determined by (seed, size), which the samples capture.
    const WINDOW: usize = 64 * 1024;
    let len = c.len();
    let mut h = Sha256::new();
    h.update(len.to_le_bytes());
    h.update(c.bytes(0, WINDOW));
    if len > WINDOW as u64 {
        h.update(c.bytes(len - WINDOW as u64, WINDOW));
    }
    // Four interior windows at deterministic offsets.
    for i in 1..=4u64 {
        let off = len / 5 * i;
        h.update(c.bytes(off, 4096));
    }
    h.finalize().into()
}

/// The central repository (Stratum-0): path → content-addressed object.
#[derive(Debug, Default)]
pub struct CvmfsRepository {
    catalog: BTreeMap<String, [u8; 32]>,
    objects: BTreeMap<[u8; 32], Content>,
    pub revision: u64,
}

impl CvmfsRepository {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish a file (new repository revision).
    pub fn publish(&mut self, path: &str, content: Content) {
        let hash = content_hash(&content);
        self.objects.insert(hash, content);
        self.catalog.insert(path.to_string(), hash);
        self.revision += 1;
    }

    pub fn lookup(&self, path: &str) -> Option<([u8; 32], u64)> {
        self.catalog
            .get(path)
            .map(|h| (*h, self.objects[h].len()))
    }

    pub fn n_paths(&self) -> usize {
        self.catalog.len()
    }

    /// Deduplicated repository size (distinct objects).
    pub fn object_bytes(&self) -> u64 {
        self.objects.values().map(|c| c.len()).sum()
    }
}

/// Per-cluster shared cache with LRU eviction.
#[derive(Debug)]
pub struct CvmfsCache {
    capacity: u64,
    used: u64,
    /// hash → size; BTreeSet keyed by (last-use counter) for LRU order.
    entries: BTreeMap<[u8; 32], (u64, u64)>, // hash -> (size, last_use)
    lru: BTreeSet<(u64, [u8; 32])>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
    wan: PerfModel,
    local: PerfModel,
}

impl CvmfsCache {
    pub fn new(capacity: u64) -> Self {
        CvmfsCache {
            capacity,
            used: 0,
            entries: BTreeMap::new(),
            lru: BTreeSet::new(),
            clock: 0,
            hits: 0,
            misses: 0,
            wan: PerfModel::wan(),
            local: PerfModel::nvme(),
        }
    }

    fn touch(&mut self, hash: [u8; 32]) {
        if let Some((size, last)) = self.entries.get(&hash).copied() {
            self.lru.remove(&(last, hash));
            self.clock += 1;
            self.entries.insert(hash, (size, self.clock));
            self.lru.insert((self.clock, hash));
        }
    }

    fn insert(&mut self, hash: [u8; 32], size: u64) {
        // Evict LRU entries until it fits.
        while self.used + size > self.capacity {
            match self.lru.iter().next().copied() {
                Some((last, victim)) => {
                    self.lru.remove(&(last, victim));
                    if let Some((vsize, _)) = self.entries.remove(&victim) {
                        self.used -= vsize;
                    }
                }
                None => break, // object larger than the whole cache
            }
        }
        if size <= self.capacity {
            self.clock += 1;
            self.entries.insert(hash, (size, self.clock));
            self.lru.insert((self.clock, hash));
            self.used += size;
        }
    }

    /// Open a path from the repository through this cache.
    pub fn open(
        &mut self,
        repo: &CvmfsRepository,
        path: &str,
    ) -> Result<(u64, Cost), String> {
        let (hash, size) = repo
            .lookup(path)
            .ok_or_else(|| format!("no such path in cvmfs: {path}"))?;
        if self.entries.contains_key(&hash) {
            self.hits += 1;
            self.touch(hash);
            Ok((size, self.local.read_cost(size)))
        } else {
            self.misses += 1;
            let mut cost = self.wan.read_cost(size);
            cost.add(self.local.write_cost(size)); // fill
            cost.add(self.wan.meta_cost(1)); // catalog lookup
            self.insert(hash, size);
            Ok((size, cost))
        }
    }

    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn used_bytes(&self) -> u64 {
        self.used
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::{GIB, MIB};

    fn repo_with(paths: &[(&str, u64)]) -> CvmfsRepository {
        let mut r = CvmfsRepository::new();
        for (i, (p, size)) in paths.iter().enumerate() {
            r.publish(p, Content::Synthetic { size: *size, seed: i as u64 });
        }
        r
    }

    #[test]
    fn second_open_hits_cache_and_is_fast() {
        let repo = repo_with(&[("sw/lhcb/gauss.sif", 2 * GIB)]);
        let mut cache = CvmfsCache::new(10 * GIB);
        let (_, miss) = cache.open(&repo, "sw/lhcb/gauss.sif").unwrap();
        let (_, hit) = cache.open(&repo, "sw/lhcb/gauss.sif").unwrap();
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 1);
        assert!(miss.seconds > 10.0 * hit.seconds);
    }

    #[test]
    fn cache_shared_across_sessions_conceptually() {
        // Two "sessions" use the same cache object: second session's
        // first open is already a hit.
        let repo = repo_with(&[("sw/common/python.sif", GIB)]);
        let mut cache = CvmfsCache::new(10 * GIB);
        cache.open(&repo, "sw/common/python.sif").unwrap(); // session A
        let (_, c) = cache.open(&repo, "sw/common/python.sif").unwrap(); // session B
        assert_eq!(cache.hit_ratio(), 0.5);
        assert!(c.seconds < 1.0);
    }

    #[test]
    fn lru_evicts_oldest() {
        let repo = repo_with(&[
            ("a", 400 * MIB),
            ("b", 400 * MIB),
            ("c", 400 * MIB),
        ]);
        let mut cache = CvmfsCache::new(GIB);
        cache.open(&repo, "a").unwrap();
        cache.open(&repo, "b").unwrap();
        cache.open(&repo, "a").unwrap(); // refresh a
        cache.open(&repo, "c").unwrap(); // evicts b (LRU)
        assert!(cache.used_bytes() <= GIB);
        cache.open(&repo, "a").unwrap();
        assert_eq!(cache.hits, 2); // a twice
        cache.open(&repo, "b").unwrap(); // b was evicted → miss
        assert_eq!(cache.misses, 4);
    }

    #[test]
    fn dedup_across_paths() {
        let mut repo = CvmfsRepository::new();
        let same = Content::Synthetic { size: MIB, seed: 9 };
        repo.publish("v1/lib.so", same.clone());
        repo.publish("v2/lib.so", same);
        assert_eq!(repo.n_paths(), 2);
        assert_eq!(repo.object_bytes(), MIB); // stored once
    }

    #[test]
    fn object_larger_than_cache_not_cached() {
        let repo = repo_with(&[("huge", 2 * GIB)]);
        let mut cache = CvmfsCache::new(GIB);
        cache.open(&repo, "huge").unwrap();
        assert_eq!(cache.used_bytes(), 0);
        cache.open(&repo, "huge").unwrap();
        assert_eq!(cache.misses, 2);
    }

    #[test]
    fn missing_path_errors() {
        let repo = repo_with(&[]);
        let mut cache = CvmfsCache::new(GIB);
        assert!(cache.open(&repo, "nope").is_err());
    }
}
