//! Storage substrate: the §3 "I/O performance spectrum", executable.
//!
//! The paper argues storage tier choice dominates iterative ML workflows
//! and offloading feasibility. Each tier here is a real in-memory
//! filesystem/object-store implementation wired to a *performance model*
//! that converts operations into simulated seconds, so the STO1 bench can
//! regenerate the spectrum and the offload stack can charge realistic
//! costs for remote data access:
//!
//! | tier | module | §3 role |
//! |---|---|---|
//! | NFS home           | [`nfs`]       | home dirs + shared volumes, bandwidth-contended |
//! | ephemeral NVMe     | [`ephemeral`] | per-session scratch on the hypervisor NVMe |
//! | object storage     | [`object`]    | Rados-GW-like S3 store, token-authenticated |
//! | rclone mount       | [`object`]    | POSIX facade over a bucket (patched-rclone) |
//! | JuiceFS            | [`juicefs`]   | distributed FS = metadata engine + S3 chunks |
//! | CVMFS              | [`cvmfs`]     | content-addressed read-only software distribution |
//! | Borg backup        | [`backup`]    | encrypted deduplicating backup of the home FS |

pub mod backup;
pub mod cvmfs;
pub mod ephemeral;
pub mod juicefs;
pub mod nfs;
pub mod object;
pub mod vfs;

/// Simulated cost of a storage operation.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cost {
    pub seconds: f64,
    pub bytes_moved: u64,
    /// Metadata round-trips (the conda-vs-apptainer killer, ENV1).
    pub meta_ops: u64,
}

impl Cost {
    pub fn zero() -> Self {
        Cost::default()
    }

    pub fn add(&mut self, other: Cost) {
        self.seconds += other.seconds;
        self.bytes_moved += other.bytes_moved;
        self.meta_ops += other.meta_ops;
    }
}

/// Throughput/latency model of a tier. Sequential bandwidth in bytes/s,
/// per-operation latency in seconds.
#[derive(Clone, Copy, Debug)]
pub struct PerfModel {
    pub read_bw: f64,
    pub write_bw: f64,
    /// Latency charged per data operation (seek/RTT).
    pub op_latency: f64,
    /// Latency charged per metadata operation (stat/create/list).
    pub meta_latency: f64,
}

impl PerfModel {
    /// Local NVMe: multi-GB/s, microsecond ops.
    pub fn nvme() -> Self {
        PerfModel {
            read_bw: 5.0e9,
            write_bw: 3.0e9,
            op_latency: 20e-6,
            meta_latency: 10e-6,
        }
    }

    /// NFS over the tenancy network (10 GbE-ish shared).
    pub fn nfs() -> Self {
        PerfModel {
            read_bw: 1.0e9,
            write_bw: 0.8e9,
            op_latency: 0.5e-3,
            meta_latency: 0.8e-3,
        }
    }

    /// Object store via HTTP (good bandwidth, expensive per-op RTT).
    pub fn object_store() -> Self {
        PerfModel {
            read_bw: 0.9e9,
            write_bw: 0.7e9,
            op_latency: 15e-3,
            meta_latency: 20e-3,
        }
    }

    /// rclone FUSE mount over the object store: same RTTs plus FUSE
    /// overhead and page-sized reads (the "bandwidth limitations of a
    /// virtual file system with a remote backend" of §3).
    pub fn rclone_mount() -> Self {
        PerfModel {
            read_bw: 0.35e9,
            write_bw: 0.25e9,
            op_latency: 25e-3,
            meta_latency: 30e-3,
        }
    }

    /// Cross-site WAN (JuiceFS data plane from a remote center).
    pub fn wan() -> Self {
        PerfModel {
            read_bw: 0.12e9,
            write_bw: 0.08e9,
            op_latency: 35e-3,
            meta_latency: 45e-3,
        }
    }

    pub fn read_cost(&self, bytes: u64) -> Cost {
        Cost {
            seconds: self.op_latency + bytes as f64 / self.read_bw,
            bytes_moved: bytes,
            meta_ops: 0,
        }
    }

    pub fn write_cost(&self, bytes: u64) -> Cost {
        Cost {
            seconds: self.op_latency + bytes as f64 / self.write_bw,
            bytes_moved: bytes,
            meta_ops: 0,
        }
    }

    pub fn meta_cost(&self, ops: u64) -> Cost {
        Cost {
            seconds: self.meta_latency * ops as f64,
            bytes_moved: 0,
            meta_ops: ops,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_bandwidth_ordering_matches_paper_spectrum() {
        // §3: ephemeral NVMe fastest … rclone/remote mounts slowest.
        assert!(PerfModel::nvme().read_bw > PerfModel::nfs().read_bw);
        assert!(PerfModel::nfs().read_bw > PerfModel::rclone_mount().read_bw);
        assert!(PerfModel::rclone_mount().read_bw > PerfModel::wan().read_bw);
    }

    #[test]
    fn cost_accumulates() {
        let m = PerfModel::nvme();
        let mut c = m.read_cost(1_000_000);
        c.add(m.meta_cost(3));
        assert!(c.seconds > 0.0);
        assert_eq!(c.bytes_moved, 1_000_000);
        assert_eq!(c.meta_ops, 3);
    }

    #[test]
    fn big_read_dominated_by_bandwidth_small_by_latency() {
        let m = PerfModel::object_store();
        let small = m.read_cost(1);
        let big = m.read_cost(10_000_000_000);
        assert!(small.seconds < 0.02);
        assert!(big.seconds > 10.0);
    }
}
