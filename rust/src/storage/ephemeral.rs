//! Ephemeral NVMe scratch volumes (§3).
//!
//! "the AI_INFN platform provides also an ephemeral file system ...
//! mapped directly to a logical volume on the hypervisor's NVMe storage.
//! The indication for the users is to copy the required data to this
//! fast volume at the beginning of each session. These ephemeral volumes
//! are also useful as a cache for intermediate results or to extend RAM
//! through memory mapping."
//!
//! Volumes are node-local: they live on a server's NVMe pool, are bound
//! to one session, and are destroyed (space reclaimed) when the session
//! ends — that is the "ephemeral" contract.

use std::collections::BTreeMap;

use super::vfs::Vfs;
use super::{Cost, PerfModel};

#[derive(Debug)]
pub struct EphemeralVolume {
    pub session: String,
    pub node: String,
    pub fs: Vfs,
}

/// Manager of per-node NVMe pools and the logical volumes carved from
/// them.
#[derive(Debug)]
pub struct EphemeralManager {
    /// node → (pool size, allocated to volumes)
    pools: BTreeMap<String, (u64, u64)>,
    volumes: BTreeMap<String, EphemeralVolume>,
    perf: PerfModel,
}

impl EphemeralManager {
    pub fn new() -> Self {
        EphemeralManager {
            pools: BTreeMap::new(),
            volumes: BTreeMap::new(),
            perf: PerfModel::nvme(),
        }
    }

    pub fn register_node(&mut self, node: &str, nvme_bytes: u64) {
        self.pools.insert(node.to_string(), (nvme_bytes, 0));
    }

    pub fn pool_free(&self, node: &str) -> Option<u64> {
        self.pools.get(node).map(|(cap, used)| cap - used)
    }

    /// Carve a logical volume for a session on its node.
    pub fn create_volume(
        &mut self,
        session: &str,
        node: &str,
        size: u64,
    ) -> Result<(), String> {
        if self.volumes.contains_key(session) {
            return Err(format!("session {session} already has a volume"));
        }
        let (cap, used) = self
            .pools
            .get_mut(node)
            .ok_or_else(|| format!("no NVMe pool on node {node}"))?;
        if *used + size > *cap {
            return Err(format!(
                "NVMe pool on {node} exhausted: {} free, {} requested",
                crate::util::bytes::human(*cap - *used),
                crate::util::bytes::human(size)
            ));
        }
        *used += size;
        self.volumes.insert(
            session.to_string(),
            EphemeralVolume {
                session: session.to_string(),
                node: node.to_string(),
                fs: Vfs::with_quota(size),
            },
        );
        Ok(())
    }

    pub fn volume(&self, session: &str) -> Option<&EphemeralVolume> {
        self.volumes.get(session)
    }

    pub fn volume_mut(&mut self, session: &str) -> Option<&mut EphemeralVolume> {
        self.volumes.get_mut(session)
    }

    /// Session teardown: destroy the volume, reclaim pool space. Data is
    /// gone — that is the documented contract.
    pub fn destroy_volume(&mut self, session: &str) -> Result<(), String> {
        let vol = self
            .volumes
            .remove(session)
            .ok_or_else(|| format!("no volume for session {session}"))?;
        let quota = vol.fs.quota_bytes.unwrap_or(0);
        if let Some((_, used)) = self.pools.get_mut(&vol.node) {
            *used = used.saturating_sub(quota);
        }
        Ok(())
    }

    /// Stage data into the volume (the recommended start-of-session copy),
    /// charged at NVMe write bandwidth (source cost charged by caller).
    pub fn stage_in(
        &mut self,
        session: &str,
        src: &Vfs,
        src_prefix: &str,
        now: f64,
    ) -> Result<(u64, Cost), String> {
        let vol = self
            .volumes
            .get_mut(session)
            .ok_or_else(|| format!("no volume for session {session}"))?;
        let (bytes, files) =
            src.copy_tree_to(src_prefix, &mut vol.fs, "scratch", now)?;
        let mut cost = self.perf.write_cost(bytes);
        cost.add(self.perf.meta_cost(files as u64));
        Ok((bytes, cost))
    }

    /// One sequential scan of the staged data (a training epoch).
    pub fn scan(&self, session: &str) -> Result<(u64, Cost), String> {
        let vol = self
            .volumes
            .get(session)
            .ok_or_else(|| format!("no volume for session {session}"))?;
        let mut cost = Cost::zero();
        let mut bytes = 0;
        for path in vol.fs.list("scratch") {
            let sz = vol.fs.stat(path).unwrap().content.len();
            bytes += sz;
            cost.add(self.perf.read_cost(sz));
            cost.add(self.perf.meta_cost(1));
        }
        Ok((bytes, cost))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::vfs::Content;
    use crate::util::bytes::{GIB, TIB};

    fn mgr() -> EphemeralManager {
        let mut m = EphemeralManager::new();
        m.register_node("server-1", 12 * TIB);
        m
    }

    #[test]
    fn create_and_destroy_reclaims_pool() {
        let mut m = mgr();
        m.create_volume("s1", "server-1", TIB).unwrap();
        assert_eq!(m.pool_free("server-1"), Some(11 * TIB));
        m.destroy_volume("s1").unwrap();
        assert_eq!(m.pool_free("server-1"), Some(12 * TIB));
    }

    #[test]
    fn pool_exhaustion_rejected() {
        let mut m = mgr();
        m.create_volume("s1", "server-1", 10 * TIB).unwrap();
        assert!(m.create_volume("s2", "server-1", 4 * TIB).is_err());
    }

    #[test]
    fn duplicate_session_rejected() {
        let mut m = mgr();
        m.create_volume("s1", "server-1", GIB).unwrap();
        assert!(m.create_volume("s1", "server-1", GIB).is_err());
    }

    #[test]
    fn data_is_gone_after_destroy() {
        let mut m = mgr();
        m.create_volume("s1", "server-1", GIB).unwrap();
        m.volume_mut("s1")
            .unwrap()
            .fs
            .write("scratch/x", Content::Real(vec![1, 2, 3]), 0.0)
            .unwrap();
        m.destroy_volume("s1").unwrap();
        m.create_volume("s1", "server-1", GIB).unwrap();
        assert!(!m.volume("s1").unwrap().fs.exists("scratch/x"));
    }

    #[test]
    fn stage_in_then_scan_is_fast() {
        let mut m = mgr();
        m.create_volume("s1", "server-1", 10 * GIB).unwrap();
        let mut src = Vfs::new();
        let mut rng = crate::util::rng::Rng::new(2);
        src.synth_dataset("ds", 10, 100 << 20, &mut rng).unwrap();
        let (bytes, stage_cost) = m.stage_in("s1", &src, "ds", 0.0).unwrap();
        assert_eq!(bytes, 1000 << 20);
        let (scanned, scan_cost) = m.scan("s1").unwrap();
        assert_eq!(scanned, bytes);
        // NVMe scan of ~1 GiB ≪ 1 s
        assert!(scan_cost.seconds < 1.0, "{}", scan_cost.seconds);
        assert!(stage_cost.seconds < 2.0);
    }

    #[test]
    fn volume_quota_enforced() {
        let mut m = mgr();
        m.create_volume("s1", "server-1", 10).unwrap();
        let vol = m.volume_mut("s1").unwrap();
        assert!(vol
            .fs
            .write("scratch/too-big", Content::Synthetic { size: 11, seed: 1 }, 0.0)
            .is_err());
    }
}
