//! In-memory POSIX-ish file tree shared by the NFS / ephemeral / JuiceFS
//! tiers.
//!
//! File *content* is either real bytes (small files: configs, notebooks)
//! or synthetic (datasets: a size + seed whose bytes are generated
//! deterministically on demand) — so a simulated 500 GB dataset costs
//! nothing to hold but still produces stable, dedupable byte streams for
//! the backup chunker.

use std::collections::BTreeMap;

use crate::util::rng::Rng;

/// File content representation.
#[derive(Clone, Debug, PartialEq)]
pub enum Content {
    /// Literal bytes, stored.
    Real(Vec<u8>),
    /// Deterministic pseudo-random stream of `size` bytes from `seed`.
    Synthetic { size: u64, seed: u64 },
}

impl Content {
    pub fn len(&self) -> u64 {
        match self {
            Content::Real(b) => b.len() as u64,
            Content::Synthetic { size, .. } => *size,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialise a byte range (synthetic streams are generated; cheap
    /// per-chunk, deterministic per (seed, offset)).
    pub fn bytes(&self, offset: u64, len: usize) -> Vec<u8> {
        match self {
            Content::Real(b) => {
                let start = (offset as usize).min(b.len());
                let end = (start + len).min(b.len());
                b[start..end].to_vec()
            }
            Content::Synthetic { size, seed } => {
                let start = offset.min(*size);
                let end = (offset + len as u64).min(*size);
                // 8-byte blocks from a per-block counter hash, so any
                // offset can be generated without streaming from zero.
                let mut out = Vec::with_capacity((end - start) as usize);
                let mut block = start / 8;
                let mut pos = start;
                while pos < end {
                    let mut s = seed ^ block.wrapping_mul(0xD6E8_FEB8_6659_FD93);
                    let word =
                        crate::util::rng::splitmix64(&mut s).to_le_bytes();
                    let in_block = (pos % 8) as usize;
                    let take =
                        ((8 - in_block) as u64).min(end - pos) as usize;
                    out.extend_from_slice(&word[in_block..in_block + take]);
                    pos += take as u64;
                    block += 1;
                }
                out
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct FileMeta {
    pub content: Content,
    pub mtime: f64,
}

/// Path-keyed file tree. Paths are `/`-separated, directories implicit
/// (like an object namespace) but directory listing and recursive ops
/// are provided; quota is enforced on total bytes.
#[derive(Clone, Debug, Default)]
pub struct Vfs {
    files: BTreeMap<String, FileMeta>,
    pub quota_bytes: Option<u64>,
    used: u64,
}

fn normalise(path: &str) -> String {
    let mut p = path.trim().trim_start_matches('/').to_string();
    while p.contains("//") {
        p = p.replace("//", "/");
    }
    p
}

impl Vfs {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_quota(quota_bytes: u64) -> Self {
        Vfs { quota_bytes: Some(quota_bytes), ..Default::default() }
    }

    pub fn used_bytes(&self) -> u64 {
        self.used
    }

    pub fn n_files(&self) -> usize {
        self.files.len()
    }

    pub fn write(
        &mut self,
        path: &str,
        content: Content,
        mtime: f64,
    ) -> Result<(), String> {
        let p = normalise(path);
        if p.is_empty() {
            return Err("empty path".into());
        }
        let new = content.len();
        let old = self.files.get(&p).map(|f| f.content.len()).unwrap_or(0);
        let next_used = self.used + new - old.min(self.used);
        if let Some(q) = self.quota_bytes {
            if next_used > q {
                return Err(format!(
                    "quota exceeded: {} > {}",
                    crate::util::bytes::human(next_used),
                    crate::util::bytes::human(q)
                ));
            }
        }
        self.used = self.used - old + new;
        self.files.insert(p, FileMeta { content, mtime });
        Ok(())
    }

    pub fn write_synthetic(
        &mut self,
        path: &str,
        size: u64,
        seed: u64,
        mtime: f64,
    ) -> Result<(), String> {
        self.write(path, Content::Synthetic { size, seed }, mtime)
    }

    pub fn stat(&self, path: &str) -> Option<&FileMeta> {
        self.files.get(&normalise(path))
    }

    pub fn exists(&self, path: &str) -> bool {
        self.stat(path).is_some()
    }

    pub fn read(&self, path: &str) -> Result<&Content, String> {
        self.files
            .get(&normalise(path))
            .map(|f| &f.content)
            .ok_or_else(|| format!("no such file: {path}"))
    }

    pub fn delete(&mut self, path: &str) -> Result<(), String> {
        let p = normalise(path);
        match self.files.remove(&p) {
            Some(f) => {
                self.used -= f.content.len();
                Ok(())
            }
            None => Err(format!("no such file: {path}")),
        }
    }

    /// All paths under a prefix (recursive "directory" listing).
    pub fn list(&self, prefix: &str) -> Vec<&str> {
        let p = normalise(prefix);
        self.files
            .keys()
            .filter(|k| {
                p.is_empty()
                    || k.as_str() == p
                    || k.starts_with(&format!("{p}/"))
            })
            .map(|k| k.as_str())
            .collect()
    }

    /// Total bytes under a prefix.
    pub fn du(&self, prefix: &str) -> u64 {
        self.list(prefix)
            .iter()
            .map(|k| self.files[*k].content.len())
            .sum()
    }

    /// Delete a whole subtree, returning files removed.
    pub fn delete_tree(&mut self, prefix: &str) -> usize {
        let victims: Vec<String> =
            self.list(prefix).iter().map(|s| s.to_string()).collect();
        for v in &victims {
            let _ = self.delete(v);
        }
        victims.len()
    }

    /// Copy a subtree into another Vfs (e.g. staging dataset → scratch).
    pub fn copy_tree_to(
        &self,
        prefix: &str,
        dest: &mut Vfs,
        dest_prefix: &str,
        mtime: f64,
    ) -> Result<(u64, usize), String> {
        let src = normalise(prefix);
        let mut bytes = 0;
        let mut files = 0;
        for path in self.list(&src) {
            let rel = path.strip_prefix(src.as_str()).unwrap_or(path);
            let rel = rel.trim_start_matches('/');
            let dst = if rel.is_empty() {
                normalise(dest_prefix)
            } else {
                format!("{}/{}", normalise(dest_prefix), rel)
            };
            let content = self.files[path].content.clone();
            bytes += content.len();
            dest.write(&dst, content, mtime)?;
            files += 1;
        }
        Ok((bytes, files))
    }

    /// Fill with a synthetic dataset layout: `n_files` of `file_size`
    /// each under `prefix` (the multi-epoch training corpus of STO1).
    pub fn synth_dataset(
        &mut self,
        prefix: &str,
        n_files: usize,
        file_size: u64,
        rng: &mut Rng,
    ) -> Result<(), String> {
        for i in 0..n_files {
            self.write_synthetic(
                &format!("{prefix}/shard-{i:05}.bin"),
                file_size,
                rng.next_u64(),
                0.0,
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip_real() {
        let mut v = Vfs::new();
        v.write("a/b.txt", Content::Real(b"hello".to_vec()), 1.0).unwrap();
        assert_eq!(v.read("/a/b.txt").unwrap().bytes(0, 10), b"hello");
        assert_eq!(v.used_bytes(), 5);
    }

    #[test]
    fn synthetic_content_deterministic_and_offset_stable() {
        let c = Content::Synthetic { size: 1000, seed: 99 };
        let all = c.bytes(0, 1000);
        assert_eq!(all.len(), 1000);
        // Range reads agree with the full stream at any offset.
        for (off, len) in [(0u64, 10usize), (3, 20), (990, 100), (512, 8)] {
            let part = c.bytes(off, len);
            let want =
                &all[off as usize..(off as usize + len).min(all.len())];
            assert_eq!(part, want, "off={off} len={len}");
        }
        // Same seed → same bytes; different seed → different bytes.
        let c2 = Content::Synthetic { size: 1000, seed: 99 };
        assert_eq!(c2.bytes(0, 1000), all);
        let c3 = Content::Synthetic { size: 1000, seed: 100 };
        assert_ne!(c3.bytes(0, 1000), all);
    }

    #[test]
    fn quota_enforced_and_overwrite_reuses() {
        let mut v = Vfs::with_quota(100);
        v.write("x", Content::Synthetic { size: 80, seed: 1 }, 0.0).unwrap();
        assert!(v.write("y", Content::Synthetic { size: 30, seed: 2 }, 0.0).is_err());
        // overwrite same file within quota is fine
        v.write("x", Content::Synthetic { size: 95, seed: 3 }, 0.0).unwrap();
        assert_eq!(v.used_bytes(), 95);
    }

    #[test]
    fn list_and_du_scope_by_prefix() {
        let mut v = Vfs::new();
        v.write("home/rosa/a", Content::Real(vec![0; 10]), 0.0).unwrap();
        v.write("home/rosa/b/c", Content::Real(vec![0; 20]), 0.0).unwrap();
        v.write("home/matteo/a", Content::Real(vec![0; 40]), 0.0).unwrap();
        assert_eq!(v.list("home/rosa").len(), 2);
        assert_eq!(v.du("home/rosa"), 30);
        assert_eq!(v.du("home"), 70);
        // prefix must match a whole component
        assert_eq!(v.list("home/ros").len(), 0);
    }

    #[test]
    fn delete_tree_frees_space() {
        let mut v = Vfs::new();
        v.write("d/1", Content::Real(vec![0; 10]), 0.0).unwrap();
        v.write("d/2", Content::Real(vec![0; 10]), 0.0).unwrap();
        v.write("e/1", Content::Real(vec![0; 10]), 0.0).unwrap();
        assert_eq!(v.delete_tree("d"), 2);
        assert_eq!(v.used_bytes(), 10);
        assert!(!v.exists("d/1"));
    }

    #[test]
    fn copy_tree_preserves_relative_layout() {
        let mut src = Vfs::new();
        src.write("data/s1", Content::Synthetic { size: 5, seed: 1 }, 0.0)
            .unwrap();
        src.write("data/sub/s2", Content::Synthetic { size: 7, seed: 2 }, 0.0)
            .unwrap();
        let mut dst = Vfs::new();
        let (bytes, files) =
            src.copy_tree_to("data", &mut dst, "scratch/data", 1.0).unwrap();
        assert_eq!((bytes, files), (12, 2));
        assert!(dst.exists("scratch/data/s1"));
        assert!(dst.exists("scratch/data/sub/s2"));
    }

    #[test]
    fn synth_dataset_layout() {
        let mut v = Vfs::new();
        let mut rng = Rng::new(1);
        v.synth_dataset("ds", 8, 1 << 20, &mut rng).unwrap();
        assert_eq!(v.n_files(), 8);
        assert_eq!(v.du("ds"), 8 << 20);
    }
}
