//! JuiceFS-like distributed file system (§3/§4).
//!
//! "JuiceFS is a cloud-based, high-performance, POSIX-compliant
//! distributed file system ... It decouples data and metadata,
//! combining a metadata engine implemented with either key-value
//! databases (such as Redis) or relational database management systems
//! (such as PostgreSQL) with storage systems accessed through S3."
//!
//! §4 uses it to ship notebooks + user environments to remote sites:
//! "the AI_INFN platform relies on [a] dedicated and distributed file
//! system based on JuiceFS using Redis as metadata engine and an S3
//! endpoint for data storage ... Relying on the distributed file system
//! drastically hinder[s] the scalability of the developed application,
//! but provides a precious intermediate level between cluster-local
//! development and multi-site distributed production."
//!
//! Implementation: file metadata (inode → chunk list) lives in a
//! pluggable [`MetadataEngine`]; file data is split into fixed-size
//! chunks stored in an [`ObjectStore`] bucket. Mounts carry a *locality*:
//! local mounts see LAN performance, remote-site mounts pay WAN costs on
//! the data plane and metadata RTTs on every operation — which is
//! exactly the "drastically hinders scalability" effect OFF1 measures.

use std::collections::BTreeMap;

use super::object::ObjectStore;
use super::vfs::Content;
use super::{Cost, PerfModel};

/// JuiceFS default chunk size (64 MiB).
pub const CHUNK_SIZE: u64 = 64 * 1024 * 1024;

/// Metadata engine abstraction (Redis-like vs PostgreSQL-like differ
/// only in per-op latency and durability model here).
pub trait MetadataEngine: std::fmt::Debug {
    fn name(&self) -> &'static str;
    fn op_latency(&self) -> f64;
    fn set(&mut self, key: &str, value: Vec<u8>);
    fn get(&self, key: &str) -> Option<&Vec<u8>>;
    fn del(&mut self, key: &str) -> bool;
    fn keys_with_prefix(&self, prefix: &str) -> Vec<String>;
    fn n_keys(&self) -> usize;
}

/// Redis-like KV engine: sub-millisecond ops.
#[derive(Debug, Default)]
pub struct RedisEngine {
    kv: BTreeMap<String, Vec<u8>>,
}

impl MetadataEngine for RedisEngine {
    fn name(&self) -> &'static str {
        "redis"
    }
    fn op_latency(&self) -> f64 {
        0.2e-3
    }
    fn set(&mut self, key: &str, value: Vec<u8>) {
        self.kv.insert(key.to_string(), value);
    }
    fn get(&self, key: &str) -> Option<&Vec<u8>> {
        self.kv.get(key)
    }
    fn del(&mut self, key: &str) -> bool {
        self.kv.remove(key).is_some()
    }
    fn keys_with_prefix(&self, prefix: &str) -> Vec<String> {
        self.kv
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, _)| k.clone())
            .collect()
    }
    fn n_keys(&self) -> usize {
        self.kv.len()
    }
}

/// PostgreSQL-like engine: transactional, ~10× the per-op latency.
#[derive(Debug, Default)]
pub struct PostgresEngine {
    kv: BTreeMap<String, Vec<u8>>,
}

impl MetadataEngine for PostgresEngine {
    fn name(&self) -> &'static str {
        "postgres"
    }
    fn op_latency(&self) -> f64 {
        2.0e-3
    }
    fn set(&mut self, key: &str, value: Vec<u8>) {
        self.kv.insert(key.to_string(), value);
    }
    fn get(&self, key: &str) -> Option<&Vec<u8>> {
        self.kv.get(key)
    }
    fn del(&mut self, key: &str) -> bool {
        self.kv.remove(key).is_some()
    }
    fn keys_with_prefix(&self, prefix: &str) -> Vec<String> {
        self.kv
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, _)| k.clone())
            .collect()
    }
    fn n_keys(&self) -> usize {
        self.kv.len()
    }
}

/// Serialised inode record: list of chunk object keys + sizes.
fn encode_inode(chunks: &[(String, u64)]) -> Vec<u8> {
    let mut out = Vec::new();
    for (key, size) in chunks {
        out.extend_from_slice(key.as_bytes());
        out.push(0);
        out.extend_from_slice(&size.to_le_bytes());
    }
    out
}

fn decode_inode(raw: &[u8]) -> Vec<(String, u64)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < raw.len() {
        let nul = raw[i..].iter().position(|&b| b == 0).unwrap() + i;
        let key = String::from_utf8(raw[i..nul].to_vec()).unwrap();
        let size =
            u64::from_le_bytes(raw[nul + 1..nul + 9].try_into().unwrap());
        out.push((key, size));
        i = nul + 9;
    }
    out
}

/// Where a mount lives relative to the metadata engine + object store.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Locality {
    /// Same tenancy (the platform cluster itself).
    Local,
    /// Remote data center reached over the WAN (§4 offloading).
    RemoteSite,
}

#[derive(Debug)]
pub struct JuiceFs<M: MetadataEngine> {
    pub meta: M,
    pub bucket: String,
    next_chunk: u64,
}

impl<M: MetadataEngine> JuiceFs<M> {
    pub fn new(meta: M, store: &mut ObjectStore, bucket: &str) -> Self {
        let _ = store.create_bucket(bucket, "juicefs-service");
        JuiceFs { meta, bucket: bucket.to_string(), next_chunk: 0 }
    }

    fn data_perf(locality: Locality) -> PerfModel {
        match locality {
            Locality::Local => PerfModel::object_store(),
            Locality::RemoteSite => PerfModel::wan(),
        }
    }

    /// Metadata RTT multiplier: remote mounts pay WAN RTT per metadata op.
    fn meta_latency(&self, locality: Locality) -> f64 {
        match locality {
            Locality::Local => self.meta.op_latency(),
            Locality::RemoteSite => self.meta.op_latency() + 30e-3,
        }
    }

    /// Write a file: split into chunks, put chunks, record inode.
    pub fn write(
        &mut self,
        store: &mut ObjectStore,
        path: &str,
        content: Content,
        locality: Locality,
        now: f64,
    ) -> Result<Cost, String> {
        let perf = Self::data_perf(locality);
        let size = content.len();
        let mut chunks = Vec::new();
        let mut cost = Cost::zero();
        let mut off = 0;
        while off < size || (size == 0 && off == 0) {
            let take = CHUNK_SIZE.min(size - off);
            let chunk_key = format!("chunks/{:016x}", self.next_chunk);
            self.next_chunk += 1;
            // Chunk payload: synthetic slice descriptor (cheap) or real bytes.
            let chunk_content = match &content {
                Content::Real(b) => Content::Real(
                    b[off as usize..(off + take) as usize].to_vec(),
                ),
                Content::Synthetic { seed, .. } => Content::Synthetic {
                    size: take,
                    seed: seed ^ off,
                },
            };
            store.service_put(&self.bucket, &chunk_key, chunk_content, now)?;
            cost.add(perf.write_cost(take));
            chunks.push((chunk_key, take));
            off += take;
            if size == 0 {
                break;
            }
        }
        self.meta.set(&format!("inode:{path}"), encode_inode(&chunks));
        cost.seconds += self.meta_latency(locality) * 2.0; // lookup+commit
        cost.meta_ops += 2;
        Ok(cost)
    }

    /// Read a whole file through a mount at `locality`.
    pub fn read(
        &mut self,
        store: &mut ObjectStore,
        path: &str,
        locality: Locality,
    ) -> Result<(u64, Cost), String> {
        let perf = Self::data_perf(locality);
        let raw = self
            .meta
            .get(&format!("inode:{path}"))
            .ok_or_else(|| format!("no such file {path}"))?
            .clone();
        let chunks = decode_inode(&raw);
        let mut cost = Cost {
            seconds: self.meta_latency(locality),
            bytes_moved: 0,
            meta_ops: 1,
        };
        let mut bytes = 0;
        for (key, size) in chunks {
            let (_c, _) = store.service_get(&self.bucket, &key)?;
            cost.add(perf.read_cost(size));
            bytes += size;
        }
        Ok((bytes, cost))
    }

    pub fn delete(
        &mut self,
        store: &mut ObjectStore,
        path: &str,
        locality: Locality,
    ) -> Result<Cost, String> {
        let _ = store;
        let key = format!("inode:{path}");
        if !self.meta.del(&key) {
            return Err(format!("no such file {path}"));
        }
        Ok(Cost {
            seconds: self.meta_latency(locality) * 2.0,
            bytes_moved: 0,
            meta_ops: 2,
        })
    }

    pub fn list(&self, prefix: &str, locality: Locality) -> (Vec<String>, Cost) {
        let keys = self.meta.keys_with_prefix(&format!("inode:{prefix}"));
        let files: Vec<String> = keys
            .iter()
            .map(|k| k.trim_start_matches("inode:").to_string())
            .collect();
        let cost = Cost {
            seconds: self.meta_latency(locality) * (1 + files.len() / 100) as f64,
            bytes_moved: 0,
            meta_ops: 1 + files.len() as u64 / 100,
        };
        (files, cost)
    }

    /// Sequential scan of a tree (an epoch over the distributed FS).
    pub fn scan(
        &mut self,
        store: &mut ObjectStore,
        prefix: &str,
        locality: Locality,
    ) -> Result<(u64, Cost), String> {
        let (files, mut cost) = self.list(prefix, locality);
        let mut bytes = 0;
        for f in files {
            let (b, c) = self.read(store, &f, locality)?;
            bytes += b;
            cost.add(c);
        }
        Ok((bytes, cost))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MIB;

    fn setup() -> (ObjectStore, JuiceFs<RedisEngine>) {
        let mut store = ObjectStore::new();
        let jfs = JuiceFs::new(RedisEngine::default(), &mut store, "jfs-data");
        (store, jfs)
    }

    #[test]
    fn write_splits_into_chunks() {
        let (mut store, mut jfs) = setup();
        let size = 3 * CHUNK_SIZE / 2; // 1.5 chunks
        jfs.write(
            &mut store,
            "envs/ml.sif",
            Content::Synthetic { size, seed: 1 },
            Locality::Local,
            0.0,
        )
        .unwrap();
        assert_eq!(store.object_count("jfs-data"), 2);
        let (bytes, _) =
            jfs.read(&mut store, "envs/ml.sif", Locality::Local).unwrap();
        assert_eq!(bytes, size);
    }

    #[test]
    fn remote_read_pays_wan_cost() {
        let (mut store, mut jfs) = setup();
        jfs.write(
            &mut store,
            "nb/train.ipynb",
            Content::Synthetic { size: 100 * MIB, seed: 2 },
            Locality::Local,
            0.0,
        )
        .unwrap();
        let (_, local) =
            jfs.read(&mut store, "nb/train.ipynb", Locality::Local).unwrap();
        let (_, remote) = jfs
            .read(&mut store, "nb/train.ipynb", Locality::RemoteSite)
            .unwrap();
        assert!(
            remote.seconds > 5.0 * local.seconds,
            "WAN {} vs LAN {}",
            remote.seconds,
            local.seconds
        );
    }

    #[test]
    fn postgres_meta_slower_than_redis() {
        let mut store = ObjectStore::new();
        let mut jfs_pg =
            JuiceFs::new(PostgresEngine::default(), &mut store, "jfs-pg");
        let (mut store2, mut jfs_redis) = setup();
        jfs_pg
            .write(&mut store, "x", Content::Real(vec![1]), Locality::Local, 0.0)
            .unwrap();
        jfs_redis
            .write(&mut store2, "x", Content::Real(vec![1]), Locality::Local, 0.0)
            .unwrap();
        let (_, pg) = jfs_pg.read(&mut store, "x", Locality::Local).unwrap();
        let (_, redis) =
            jfs_redis.read(&mut store2, "x", Locality::Local).unwrap();
        assert!(pg.seconds > redis.seconds);
    }

    #[test]
    fn list_and_scan_tree() {
        let (mut store, mut jfs) = setup();
        for i in 0..5 {
            jfs.write(
                &mut store,
                &format!("proj/file-{i}"),
                Content::Synthetic { size: MIB, seed: i },
                Locality::Local,
                0.0,
            )
            .unwrap();
        }
        let (files, _) = jfs.list("proj/", Locality::Local);
        assert_eq!(files.len(), 5);
        let (bytes, _) =
            jfs.scan(&mut store, "proj/", Locality::Local).unwrap();
        assert_eq!(bytes, 5 * MIB);
    }

    #[test]
    fn delete_removes_metadata() {
        let (mut store, mut jfs) = setup();
        jfs.write(&mut store, "x", Content::Real(vec![1]), Locality::Local, 0.0)
            .unwrap();
        jfs.delete(&mut store, "x", Locality::Local).unwrap();
        assert!(jfs.read(&mut store, "x", Locality::Local).is_err());
        assert!(jfs.delete(&mut store, "x", Locality::Local).is_err());
    }

    #[test]
    fn inode_codec_roundtrip() {
        let chunks = vec![
            ("chunks/0000000000000001".to_string(), CHUNK_SIZE),
            ("chunks/00000000000000ff".to_string(), 12345),
        ];
        assert_eq!(decode_inode(&encode_inode(&chunks)), chunks);
    }

    #[test]
    fn empty_file_roundtrip() {
        let (mut store, mut jfs) = setup();
        jfs.write(&mut store, "empty", Content::Real(vec![]), Locality::Local, 0.0)
            .unwrap();
        let (bytes, _) = jfs.read(&mut store, "empty", Locality::Local).unwrap();
        assert_eq!(bytes, 0);
    }
}
