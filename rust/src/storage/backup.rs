//! BorgBackup-like encrypted deduplicating backup (§3).
//!
//! "The platform file system is subject to regular encrypted backup.
//! Backup data is stored in a remote Ceph volume provisioned by INFN
//! Cloud using the BorgBackup package to ensure data deduplication."
//!
//! Real mechanics, small scale: content-defined chunking with a rolling
//! hash (Buzhash-style), SHA-256-addressed chunk store, AES-128-CTR
//! encryption of chunk payloads, and per-archive manifests — enough to
//! measure true dedup ratios across nightly runs of slowly-changing home
//! directories (experiment STO1-side metric) and to verify restores
//! byte-for-byte.

use aes::cipher::{BlockEncrypt, KeyInit};
use aes::Aes128;
use sha2::{Digest, Sha256};
use std::collections::BTreeMap;

use super::vfs::Vfs;
use super::{Cost, PerfModel};

/// Chunking parameters (Borg defaults scaled down for test speed).
pub const MIN_CHUNK: usize = 512;
pub const TARGET_MASK: u64 = (1 << 12) - 1; // avg ~4 KiB chunks
pub const MAX_CHUNK: usize = 64 * 1024;

/// Byte → random u64 table for the Buzhash (deterministic, generated
/// once from a fixed seed).
fn buz_table() -> &'static [u64; 256] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u64; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u64; 256];
        let mut s = 0xB0C2_0FFE_E5EEDu64;
        for slot in t.iter_mut() {
            *slot = crate::util::rng::splitmix64(&mut s);
        }
        t
    })
}

/// Content-defined chunk boundaries via a Buzhash (cyclic polynomial)
/// over a 64-byte rolling window — boundaries depend only on the local
/// window content, so insertions shift chunk edges, not the whole
/// stream (the property Borg's dedup relies on). Returns chunk lengths
/// covering the whole input.
pub fn chunk_boundaries(data: &[u8]) -> Vec<usize> {
    // WINDOW must be ≡ 0 (mod 64) so the removal term needs no rotate.
    const WINDOW: usize = 64;
    let table = buz_table();
    let mut chunks = Vec::new();
    let mut start = 0;
    while start < data.len() {
        let mut h: u64 = 0;
        let mut end = start;
        let limit = (start + MAX_CHUNK).min(data.len());
        let mut cut = limit;
        while end < limit {
            h = h.rotate_left(1) ^ table[data[end] as usize];
            if end >= start + WINDOW {
                // Remove the byte leaving the window (rotated WINDOW
                // times ≡ identity since WINDOW % 64 == 0).
                h ^= table[data[end - WINDOW] as usize];
            }
            if end - start >= MIN_CHUNK && (h & TARGET_MASK) == 0 {
                cut = end + 1;
                break;
            }
            end += 1;
        }
        chunks.push(cut - start);
        start = cut;
    }
    chunks
}

fn sha(data: &[u8]) -> [u8; 32] {
    Sha256::digest(data).into()
}

/// AES-128-CTR keystream encryption (CTR built on the block cipher; the
/// `ctr` mode crate is not in the offline set).
pub fn aes_ctr(key: &[u8; 16], nonce: u64, data: &[u8]) -> Vec<u8> {
    let cipher = Aes128::new(key.into());
    let mut out = Vec::with_capacity(data.len());
    let mut counter: u128 = (nonce as u128) << 64;
    for block in data.chunks(16) {
        let mut ks = counter.to_be_bytes();
        cipher.encrypt_block((&mut ks).into());
        for (i, b) in block.iter().enumerate() {
            out.push(b ^ ks[i]);
        }
        counter += 1;
    }
    out
}

/// One archive (a nightly run) in the repository.
#[derive(Clone, Debug)]
pub struct Archive {
    pub name: String,
    /// file path → ordered chunk ids.
    pub manifest: BTreeMap<String, Vec<[u8; 32]>>,
    pub original_bytes: u64,
    /// Bytes of *new* chunks this archive added.
    pub new_bytes: u64,
}

/// The deduplicating, encrypted repository (remote Ceph volume).
pub struct BackupRepo {
    key: [u8; 16],
    chunks: BTreeMap<[u8; 32], Vec<u8>>, // id → encrypted payload
    archives: Vec<Archive>,
    perf: PerfModel,
    nonce_counter: u64,
    nonces: BTreeMap<[u8; 32], u64>,
}

impl std::fmt::Debug for BackupRepo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BackupRepo")
            .field("archives", &self.archives.len())
            .field("chunks", &self.chunks.len())
            .finish()
    }
}

impl BackupRepo {
    pub fn new(key_seed: u64) -> Self {
        let mut key = [0u8; 16];
        let mut s = key_seed;
        for c in key.chunks_mut(8) {
            c.copy_from_slice(&crate::util::rng::splitmix64(&mut s).to_le_bytes());
        }
        BackupRepo {
            key,
            chunks: BTreeMap::new(),
            archives: Vec::new(),
            perf: PerfModel::wan(),
            nonce_counter: 0,
            nonces: BTreeMap::new(),
        }
    }

    /// Run a backup of `fs` as archive `name`. Returns (archive index,
    /// simulated cost): only new chunks cross the wire (Borg's point).
    pub fn backup(&mut self, name: &str, fs: &Vfs) -> (usize, Cost) {
        let mut manifest = BTreeMap::new();
        let mut original = 0u64;
        let mut new_bytes = 0u64;
        let mut cost = Cost::zero();

        for path in fs.list("") {
            let content = &fs.stat(path).unwrap().content;
            let len = content.len();
            original += len;
            // Stream file content in 1 MiB windows through the chunker.
            // (For synthetic content this materialises windows on demand.)
            let mut ids = Vec::new();
            let mut off = 0u64;
            while off < len || (len == 0 && off == 0) {
                let take = (1u64 << 20).min(len - off) as usize;
                let window = content.bytes(off, take);
                let mut pos = 0usize;
                for clen in chunk_boundaries(&window) {
                    let chunk = &window[pos..pos + clen];
                    pos += clen;
                    let id = sha(chunk);
                    if !self.chunks.contains_key(&id) {
                        self.nonce_counter += 1;
                        let nonce = self.nonce_counter;
                        let enc = aes_ctr(&self.key, nonce, chunk);
                        cost.add(self.perf.write_cost(enc.len() as u64));
                        new_bytes += enc.len() as u64;
                        self.nonces.insert(id, nonce);
                        self.chunks.insert(id, enc);
                    }
                    // Dedup hits cost nothing on the wire: Borg keeps
                    // the chunk index client-side.
                    ids.push(id);
                }
                off += take as u64;
                if len == 0 {
                    break;
                }
            }
            manifest.insert(path.to_string(), ids);
            // One manifest write per file.
            cost.add(self.perf.meta_cost(1));
        }

        let idx = self.archives.len();
        self.archives.push(Archive {
            name: name.to_string(),
            manifest,
            original_bytes: original,
            new_bytes,
        });
        (idx, cost)
    }

    pub fn archives(&self) -> &[Archive] {
        &self.archives
    }

    /// Stored (encrypted, deduplicated) bytes.
    pub fn stored_bytes(&self) -> u64 {
        self.chunks.values().map(|c| c.len() as u64).sum()
    }

    /// Overall dedup ratio: original bytes across archives / stored.
    pub fn dedup_ratio(&self) -> f64 {
        let original: u64 =
            self.archives.iter().map(|a| a.original_bytes).sum();
        let stored = self.stored_bytes();
        if stored == 0 {
            return 1.0;
        }
        original as f64 / stored as f64
    }

    /// Restore a file from an archive, verifying chunk hashes.
    pub fn restore(
        &self,
        archive: usize,
        path: &str,
    ) -> Result<Vec<u8>, String> {
        let a = self
            .archives
            .get(archive)
            .ok_or_else(|| format!("no archive {archive}"))?;
        let ids = a
            .manifest
            .get(path)
            .ok_or_else(|| format!("no file {path} in archive"))?;
        let mut out = Vec::new();
        for id in ids {
            let enc = self
                .chunks
                .get(id)
                .ok_or_else(|| "missing chunk (repo corrupt)".to_string())?;
            let nonce = self.nonces[id];
            let plain = aes_ctr(&self.key, nonce, enc);
            if sha(&plain) != *id {
                return Err("chunk hash mismatch after decrypt".into());
            }
            out.extend_from_slice(&plain);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::vfs::Content;
    use crate::util::rng::Rng;

    fn home_fs(seed: u64, n_files: usize, file_kib: u64) -> Vfs {
        let mut fs = Vfs::new();
        let mut rng = Rng::new(seed);
        for i in 0..n_files {
            fs.write_synthetic(
                &format!("home/rosa/f{i}"),
                file_kib * 1024,
                rng.next_u64(),
                0.0,
            )
            .unwrap();
        }
        fs
    }

    #[test]
    fn chunk_boundaries_cover_input_exactly() {
        let mut rng = Rng::new(3);
        for size in [0usize, 1, 511, 512, 4096, 100_000, 300_000] {
            let data: Vec<u8> =
                (0..size).map(|_| rng.next_u64() as u8).collect();
            let chunks = chunk_boundaries(&data);
            assert_eq!(chunks.iter().sum::<usize>(), size, "size {size}");
            for (i, c) in chunks.iter().enumerate() {
                assert!(*c <= MAX_CHUNK);
                // all but the final chunk respect the minimum
                if i + 1 < chunks.len() {
                    assert!(*c >= MIN_CHUNK, "chunk {i} of {size}: {c}");
                }
            }
        }
    }

    #[test]
    fn chunking_is_shift_resistant() {
        // Insert bytes at the front; most chunk hashes must survive —
        // the property fixed-size chunking lacks.
        let mut rng = Rng::new(4);
        let data: Vec<u8> =
            (0..200_000).map(|_| rng.next_u64() as u8).collect();
        let mut shifted = vec![0xAA; 7];
        shifted.extend_from_slice(&data);

        let hashes = |d: &[u8]| -> std::collections::BTreeSet<[u8; 32]> {
            let mut pos = 0;
            chunk_boundaries(d)
                .into_iter()
                .map(|l| {
                    let h = sha(&d[pos..pos + l]);
                    pos += l;
                    h
                })
                .collect()
        };
        let a = hashes(&data);
        let b = hashes(&shifted);
        let common = a.intersection(&b).count();
        assert!(
            common as f64 >= 0.5 * a.len() as f64,
            "only {common}/{} chunks survived a 7-byte shift",
            a.len()
        );
    }

    #[test]
    fn aes_ctr_roundtrip_and_nonce_sensitivity() {
        let key = [7u8; 16];
        let msg = b"the platform file system is subject to regular encrypted backup";
        let enc = aes_ctr(&key, 1, msg);
        assert_ne!(&enc[..], &msg[..]);
        let dec = aes_ctr(&key, 1, &enc);
        assert_eq!(&dec[..], &msg[..]);
        let enc2 = aes_ctr(&key, 2, msg);
        assert_ne!(enc, enc2);
    }

    #[test]
    fn unchanged_second_backup_dedups_fully() {
        let fs = home_fs(1, 20, 64);
        let mut repo = BackupRepo::new(9);
        let (_, first) = repo.backup("night-1", &fs);
        let stored_after_first = repo.stored_bytes();
        let (_, second) = repo.backup("night-2", &fs);
        assert_eq!(repo.stored_bytes(), stored_after_first);
        assert!(repo.archives()[1].new_bytes == 0);
        assert!(second.seconds < first.seconds / 5.0);
        assert!(repo.dedup_ratio() > 1.9);
    }

    #[test]
    fn small_change_uploads_little() {
        let mut fs = home_fs(2, 10, 128);
        let mut repo = BackupRepo::new(9);
        repo.backup("night-1", &fs);
        // change one file out of ten
        fs.write_synthetic("home/rosa/f3", 128 * 1024, 0xDEAD, 1.0).unwrap();
        let (_, _) = repo.backup("night-2", &fs);
        let a = &repo.archives()[1];
        assert!(
            a.new_bytes < a.original_bytes / 5,
            "new {} vs original {}",
            a.new_bytes,
            a.original_bytes
        );
    }

    #[test]
    fn restore_roundtrips_bytes() {
        let mut fs = Vfs::new();
        let payload: Vec<u8> = (0..300_000u32).map(|i| (i * 7 % 251) as u8).collect();
        fs.write("home/rosa/data.bin", Content::Real(payload.clone()), 0.0)
            .unwrap();
        let mut repo = BackupRepo::new(11);
        let (idx, _) = repo.backup("n1", &fs);
        let restored = repo.restore(idx, "home/rosa/data.bin").unwrap();
        assert_eq!(restored, payload);
    }

    #[test]
    fn restore_missing_file_errors() {
        let fs = home_fs(3, 1, 1);
        let mut repo = BackupRepo::new(1);
        let (idx, _) = repo.backup("n1", &fs);
        assert!(repo.restore(idx, "nope").is_err());
        assert!(repo.restore(99, "home/rosa/f0").is_err());
    }

    #[test]
    fn encrypted_at_rest() {
        let mut fs = Vfs::new();
        let secret = vec![0x42u8; 100_000];
        fs.write("home/rosa/secret", Content::Real(secret.clone()), 0.0)
            .unwrap();
        let mut repo = BackupRepo::new(5);
        repo.backup("n1", &fs);
        // No stored chunk may contain a long run of the plaintext byte.
        for enc in repo.chunks.values() {
            let longest_run = enc
                .split(|b| *b != 0x42)
                .map(|r| r.len())
                .max()
                .unwrap_or(0);
            assert!(longest_run < 8, "plaintext visible at rest");
        }
    }
}
