//! The platform NFS file system (§3).
//!
//! "The main platform file system is distributed through the containers
//! via NFS. One of the platform nodes runs an NFS server in a Kubernetes
//! pod and exports data to the containers spawned by JupyterHub. At
//! spawn time, JupyterHub is configured to create the user's home
//! directories and project-dedicated shared volumes."
//!
//! The server's NIC bandwidth is *shared*: with `k` concurrent active
//! clients each sees `bw/k` — which is exactly why §3 recommends copying
//! datasets to the ephemeral NVMe volume for iterative training (STO1
//! regenerates that crossover).

use super::vfs::{Content, Vfs};
use super::{Cost, PerfModel};

#[derive(Debug)]
pub struct NfsServer {
    pub fs: Vfs,
    perf: PerfModel,
    /// Currently active clients (sessions with the mount doing I/O).
    active_clients: u32,
    /// Per-user home quota.
    pub home_quota: u64,
}

impl NfsServer {
    pub fn new(home_quota: u64) -> Self {
        NfsServer {
            fs: Vfs::new(),
            perf: PerfModel::nfs(),
            active_clients: 0,
            home_quota,
        }
    }

    /// Contention factor: effective bandwidth divisor.
    fn contention(&self) -> f64 {
        self.active_clients.max(1) as f64
    }

    pub fn client_attached(&mut self) {
        self.active_clients += 1;
    }

    pub fn client_detached(&mut self) {
        self.active_clients = self.active_clients.saturating_sub(1);
    }

    pub fn active_clients(&self) -> u32 {
        self.active_clients
    }

    /// JupyterHub spawn hook: create home dir + skeleton.
    pub fn provision_home(&mut self, user: &str, now: f64) -> Cost {
        let mut cost = Cost::zero();
        if !self.fs.exists(&format!("home/{user}/.keep")) {
            for (path, data) in [
                (format!("home/{user}/.keep"), &b""[..]),
                (
                    format!("home/{user}/.bashrc"),
                    &b"export PS1='ai-infn$ '\n"[..],
                ),
                (
                    format!("home/{user}/README.md"),
                    &b"# AI_INFN home\nSee /envs for managed environments.\n"[..],
                ),
            ] {
                self.fs
                    .write(&path, Content::Real(data.to_vec()), now)
                    .expect("home provisioning within quota");
                cost.add(self.perf.meta_cost(2)); // create + setattr
            }
        }
        cost
    }

    /// Provision a project-dedicated shared volume.
    pub fn provision_shared(&mut self, project: &str, now: f64) -> Cost {
        let path = format!("shared/{project}/.keep");
        let mut cost = Cost::zero();
        if !self.fs.exists(&path) {
            self.fs.write(&path, Content::Real(vec![]), now).unwrap();
            cost.add(self.perf.meta_cost(2));
        }
        cost
    }

    /// Read a file, charged at the contended bandwidth.
    pub fn read(&self, path: &str) -> Result<(u64, Cost), String> {
        let content = self.fs.read(path)?;
        let bytes = content.len();
        let mut c = self.perf.read_cost(bytes);
        c.seconds = self.perf.op_latency
            + bytes as f64 / (self.perf.read_bw / self.contention());
        Ok((bytes, c))
    }

    /// Write a file, charged at the contended bandwidth.
    pub fn write(
        &mut self,
        path: &str,
        content: Content,
        now: f64,
    ) -> Result<Cost, String> {
        // Per-user quota on home paths.
        if let Some(rest) = path.trim_start_matches('/').strip_prefix("home/") {
            if let Some(user) = rest.split('/').next() {
                let used = self.fs.du(&format!("home/{user}"));
                if used + content.len() > self.home_quota {
                    return Err(format!(
                        "home quota exceeded for {user}: {} + {} > {}",
                        crate::util::bytes::human(used),
                        crate::util::bytes::human(content.len()),
                        crate::util::bytes::human(self.home_quota)
                    ));
                }
            }
        }
        let bytes = content.len();
        self.fs.write(path, content, now)?;
        let mut c = self.perf.write_cost(bytes);
        c.seconds = self.perf.op_latency
            + bytes as f64 / (self.perf.write_bw / self.contention());
        c.add(self.perf.meta_cost(1));
        Ok(c)
    }

    /// Scan a dataset sequentially (one training epoch's worth of reads).
    pub fn scan_tree(&self, prefix: &str) -> (u64, Cost) {
        let mut total = Cost::zero();
        let mut bytes = 0;
        for path in self.fs.list(prefix) {
            let sz = self.fs.stat(path).unwrap().content.len();
            bytes += sz;
            let mut c = self.perf.read_cost(sz);
            c.seconds = self.perf.op_latency
                + sz as f64 / (self.perf.read_bw / self.contention());
            total.add(c);
            total.add(self.perf.meta_cost(1));
        }
        (bytes, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::GIB;

    #[test]
    fn provision_home_is_idempotent() {
        let mut s = NfsServer::new(10 * GIB);
        let c1 = s.provision_home("rosa", 0.0);
        let files = s.fs.n_files();
        let c2 = s.provision_home("rosa", 1.0);
        assert_eq!(s.fs.n_files(), files);
        assert!(c1.seconds > 0.0);
        assert_eq!(c2.seconds, 0.0);
    }

    #[test]
    fn contention_slows_reads_linearly() {
        let mut s = NfsServer::new(10 * GIB);
        s.fs
            .write("home/rosa/data.bin", Content::Synthetic { size: GIB, seed: 1 }, 0.0)
            .unwrap();
        s.client_attached();
        let (_, solo) = s.read("home/rosa/data.bin").unwrap();
        for _ in 0..9 {
            s.client_attached();
        }
        let (_, crowded) = s.read("home/rosa/data.bin").unwrap();
        assert!(
            crowded.seconds > 8.0 * solo.seconds,
            "10 clients should see ~10x slowdown: {} vs {}",
            crowded.seconds,
            solo.seconds
        );
    }

    #[test]
    fn home_quota_enforced_per_user() {
        let mut s = NfsServer::new(GIB);
        s.write(
            "home/rosa/big.bin",
            Content::Synthetic { size: GIB / 2, seed: 1 },
            0.0,
        )
        .unwrap();
        assert!(s
            .write(
                "home/rosa/big2.bin",
                Content::Synthetic { size: GIB, seed: 2 },
                0.0,
            )
            .is_err());
        // another user is unaffected
        s.write(
            "home/matteo/big.bin",
            Content::Synthetic { size: GIB / 2, seed: 3 },
            0.0,
        )
        .unwrap();
    }

    #[test]
    fn scan_tree_charges_meta_per_file() {
        let mut s = NfsServer::new(100 * GIB);
        let mut rng = crate::util::rng::Rng::new(5);
        s.fs.synth_dataset("home/rosa/ds", 100, 1 << 20, &mut rng).unwrap();
        let (bytes, cost) = s.scan_tree("home/rosa/ds");
        assert_eq!(bytes, 100 << 20);
        assert_eq!(cost.meta_ops, 100);
        assert!(cost.seconds > 0.1); // 100 MiB at ~1 GB/s + latencies
    }

    #[test]
    fn detach_never_underflows() {
        let mut s = NfsServer::new(GIB);
        s.client_detached();
        assert_eq!(s.active_clients(), 0);
    }
}
