//! The generic remote-site queueing engine behind every interLink plugin.
//!
//! Each batch system gets its scheduler's signature dynamics:
//!
//! * **HTCondor** (INFN-Tier-1): jobs become startable only at
//!   *negotiation cycles* (fair-share matchmaking every ~minutes), then
//!   start in bulk — Fig. 2's `infncnaf` staircase.
//! * **Slurm** (Leonardo, Terabit-Padova): priority queue with a
//!   scheduling interval plus *backfill* — short jobs may jump ahead
//!   when slots are free; big HPC centers add a long base queue wait.
//! * **Podman** (cloud VM): no batch system at all — container starts
//!   immediately if a slot is free, otherwise the create call queues
//!   locally in the plugin shim; tiny capacity, near-zero delay.
//! * **Kubernetes** (recas Tier-2, the §4 "production soon" plugin):
//!   continuous scheduling loop with per-pod image pull.
//!
//! All sampling is seeded → Fig. 2 regenerates byte-identically.

use std::collections::BTreeMap;

use super::interlink::{
    InterLinkPlugin, JobDescriptor, RemoteJobId, RemoteState,
};
use crate::sim::Time;
use crate::util::rng::Rng;

/// §4: "secrets to access confidential data cannot be shared with a
/// remote data center" and the shared FS is mounted only "if allowed by
/// site-specific policies".
#[derive(Clone, Copy, Debug)]
pub struct SitePolicy {
    pub allow_fuse_mounts: bool,
    pub allow_secrets: bool,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SiteKind {
    HtCondor,
    Slurm,
    Podman,
    Kubernetes,
}

/// Site calibration: capacity + delay distributions.
#[derive(Clone, Debug)]
pub struct SiteParams {
    pub kind: SiteKind,
    /// Execution slots available to this tenancy.
    pub slots: usize,
    /// Submission RTT (client → CE/API).
    pub submit_latency: f64,
    /// Scheduler pass period (negotiation cycle / sched interval).
    pub sched_interval: f64,
    /// Median extra queue wait imposed by site load (lognormal median).
    pub queue_wait_median: f64,
    pub queue_wait_sigma: f64,
    /// Container/image setup once matched.
    pub startup_time: f64,
    /// Slurm backfill: jobs shorter than this may jump the queue.
    pub backfill_threshold: f64,
    /// Probability a job fails at the site.
    pub failure_prob: f64,
    pub policy: SitePolicy,
    /// Advertised virtual-node capacity.
    pub cpu_capacity_m: u64,
    pub mem_capacity: u64,
}

#[derive(Clone, Debug)]
struct SiteJob {
    #[allow(dead_code)]
    id: RemoteJobId,
    desc: JobDescriptor,
    state: RemoteState,
    /// When the job becomes eligible to be matched (submit + queue wait).
    eligible_at: Time,
    /// Set when matched: when it transitions Starting → Running.
    run_at: Time,
    /// Set when running: completion time.
    done_at: Time,
    will_fail: bool,
}

/// The engine: one instance per site, driven by `tick(now)`.
#[derive(Debug)]
pub struct SiteModel {
    pub name: String,
    pub params: SiteParams,
    jobs: BTreeMap<RemoteJobId, SiteJob>,
    next_id: u64,
    rng: Rng,
    /// Next scheduler pass (HTCondor negotiation / Slurm sched).
    next_sched_pass: Time,
    /// WAN outage windows `[from, until)` — installed up front by the
    /// chaos layer. During a window every `create` is refused at the
    /// very top (before the policy gates and before any RNG draw, so
    /// an outage cannot skew the site's random stream); jobs already
    /// at the site keep draining their own queue.
    outages: Vec<(Time, Time)>,
    /// Lifetime counters for the experiments.
    pub n_created: u64,
    pub n_succeeded: u64,
    pub n_failed: u64,
    pub n_rejected: u64,
}

impl SiteModel {
    pub fn new(name: &str, params: SiteParams, seed: u64) -> Self {
        SiteModel {
            name: name.to_string(),
            params,
            jobs: BTreeMap::new(),
            next_id: 0,
            rng: Rng::new(seed),
            next_sched_pass: 0.0,
            outages: Vec::new(),
            n_created: 0,
            n_succeeded: 0,
            n_failed: 0,
            n_rejected: 0,
        }
    }

    /// Install a WAN outage window `[from, until)` (chaos layer).
    pub fn add_outage(&mut self, from: Time, until: Time) {
        if until > from {
            self.outages.push((from, until));
        }
    }

    /// Whether `now` falls inside an installed outage window.
    pub fn in_outage(&self, now: Time) -> bool {
        self.outages
            .iter()
            .any(|&(from, until)| now >= from && now < until)
    }

    fn slots_busy(&self) -> usize {
        self.jobs
            .values()
            .filter(|j| {
                matches!(j.state, RemoteState::Starting | RemoteState::Running)
            })
            .count()
    }

    pub fn free_slots(&self) -> usize {
        self.params.slots.saturating_sub(self.slots_busy())
    }

    /// Match eligible queued jobs to free slots (one scheduler pass).
    fn scheduler_pass(&mut self, now: Time) {
        let mut free = self.free_slots();
        if free == 0 {
            return;
        }
        // Eligible = past their queue wait. Slurm backfill: short jobs
        // are eligible early when slots are free.
        let mut candidates: Vec<RemoteJobId> = self
            .jobs
            .iter()
            .filter(|(_, j)| j.state == RemoteState::Queued)
            .filter(|(_, j)| {
                j.eligible_at <= now
                    || (self.params.kind == SiteKind::Slurm
                        && j.desc.runtime_s < self.params.backfill_threshold)
            })
            .map(|(id, _)| *id)
            .collect();
        candidates.sort(); // FIFO by submission order (id order)
        for id in candidates {
            if free == 0 {
                break;
            }
            let startup = self.params.startup_time
                * self.rng.uniform(0.8, 1.3);
            let job = self.jobs.get_mut(&id).unwrap();
            job.state = RemoteState::Starting;
            job.run_at = now + startup;
            job.done_at = job.run_at + job.desc.runtime_s;
            free -= 1;
        }
    }

    /// Advance the scheduler-pass boundary chain past `now` (fixed
    /// cadence: whole intervals from the initial boundary). Called by
    /// every tick and by `create`, so the chain's position is a
    /// function of the current time alone — never of which tick
    /// happened to observe a boundary.
    fn consume_boundaries(&mut self, now: Time) {
        let interval = self.params.sched_interval.max(1e-9);
        while self.next_sched_pass <= now {
            self.next_sched_pass += interval;
        }
    }

    fn advance_lifecycles(&mut self, now: Time) {
        let mut finished = Vec::new();
        for (id, job) in self.jobs.iter_mut() {
            match job.state {
                RemoteState::Starting if now >= job.run_at => {
                    job.state = RemoteState::Running;
                }
                _ => {}
            }
            if job.state == RemoteState::Running && now >= job.done_at {
                job.state = if job.will_fail {
                    RemoteState::Failed
                } else {
                    RemoteState::Succeeded
                };
                finished.push((*id, job.will_fail));
            }
        }
        for (_, failed) in finished {
            if failed {
                self.n_failed += 1;
            } else {
                self.n_succeeded += 1;
            }
        }
    }

    pub fn jobs_in_state(&self, state: RemoteState) -> usize {
        self.jobs.values().filter(|j| j.state == state).count()
    }

    /// Earliest future instant at which a `tick` could change this
    /// site's state — the edge the reactive coordinator schedules its
    /// next reconcile around. `None` means the site is quiescent: any
    /// tick before the next external `create` is a provable no-op
    /// (`advance_lifecycles` finds nothing to advance, and under the
    /// fixed pass cadence an empty/overfull scheduler pass mutates
    /// nothing observable).
    ///
    /// Sources, mirroring exactly what `tick(now)` reads:
    ///  * `Starting` jobs transition at `run_at`;
    ///  * `Running` jobs finish at `done_at`;
    ///  * `Queued` jobs can be matched — for podman/k8s at their
    ///    eligibility instant while a slot is free (a full site cannot
    ///    match, and the slot-freeing `done_at` is already a reported
    ///    edge; their pass keeps no boundary state, so a no-match tick
    ///    is a pure no-op); for batch systems at the next scheduler
    ///    pass boundary *regardless of free slots* — the `tick` that
    ///    observes a boundary consumes it (`next_sched_pass` advances),
    ///    so skipping even a full-site pass would shift every later
    ///    pass relative to a dense poller.
    pub fn next_transition_after(&self, now: Time) -> Option<Time> {
        let mut next = f64::INFINITY;
        let free = self.free_slots();
        let mut queued_any = false;
        for job in self.jobs.values() {
            match job.state {
                RemoteState::Starting => next = next.min(job.run_at),
                RemoteState::Running => next = next.min(job.done_at),
                RemoteState::Queued => {
                    queued_any = true;
                    if free > 0
                        && matches!(
                            self.params.kind,
                            SiteKind::Podman | SiteKind::Kubernetes
                        )
                    {
                        next = next.min(job.eligible_at.max(now));
                    }
                }
                _ => {}
            }
        }
        if queued_any
            && matches!(self.params.kind, SiteKind::HtCondor | SiteKind::Slurm)
        {
            next = next.min(self.next_sched_pass.max(now));
        }
        next.is_finite().then_some(next)
    }
}

impl InterLinkPlugin for SiteModel {
    fn name(&self) -> &str {
        &self.name
    }

    fn create(&mut self, job: JobDescriptor, now: Time) -> Result<RemoteJobId, String> {
        // Outage gate FIRST: an unreachable site refuses before the
        // policy gates and before any RNG draw, so an outage window
        // leaves the site's random stream byte-identical to a run
        // where those creates never happened.
        if self.in_outage(now) {
            self.n_rejected += 1;
            return Err(format!("site {} unreachable (outage)", self.name));
        }
        // §4 policy gates.
        if job.needs_shared_fs && !self.params.policy.allow_fuse_mounts {
            self.n_rejected += 1;
            return Err(format!(
                "site {} forbids FUSE mounts (shared fs required)",
                self.name
            ));
        }
        if !job.secrets.is_empty() && !self.params.policy.allow_secrets {
            self.n_rejected += 1;
            return Err(format!(
                "site {} policy forbids shipped secrets",
                self.name
            ));
        }
        // Podman: no queue — a created container occupies the VM from
        // the moment of creation; refuse when full (the shim retries).
        if self.params.kind == SiteKind::Podman {
            let occupied = self
                .jobs
                .values()
                .filter(|j| !j.state.is_terminal())
                .count();
            if occupied >= self.params.slots {
                self.n_rejected += 1;
                return Err(format!("podman VM {} full", self.name));
            }
        }
        // Boundaries that elapsed while the site was quiescent (no
        // ticks needed) were consumed on schedule by a dense poller's
        // empty passes; consume them here so the first pass that can
        // see this job lands at the same boundary under sparse ticking.
        if matches!(self.params.kind, SiteKind::HtCondor | SiteKind::Slurm) {
            self.consume_boundaries(now);
        }
        self.next_id += 1;
        let id = RemoteJobId(self.next_id);
        let wait = if self.params.queue_wait_median > 0.0 {
            self.rng.lognormal(
                self.params.queue_wait_median,
                self.params.queue_wait_sigma,
            )
        } else {
            0.0
        };
        let will_fail = self.rng.bool(self.params.failure_prob);
        self.jobs.insert(
            id,
            SiteJob {
                id,
                desc: job,
                state: RemoteState::Queued,
                eligible_at: now + self.params.submit_latency + wait,
                run_at: f64::INFINITY,
                done_at: f64::INFINITY,
                will_fail,
            },
        );
        self.n_created += 1;
        Ok(id)
    }

    fn status(&self, id: RemoteJobId) -> Option<RemoteState> {
        self.jobs.get(&id).map(|j| j.state)
    }

    fn logs(&self, id: RemoteJobId) -> String {
        match self.jobs.get(&id) {
            Some(j) => format!(
                "[{}] job {} state={:?} cmd={:?}",
                self.name, id.0, j.state, j.desc.command
            ),
            None => format!("[{}] job {} unknown", self.name, id.0),
        }
    }

    fn delete(&mut self, id: RemoteJobId) -> Result<(), String> {
        self.jobs
            .remove(&id)
            .map(|_| ())
            .ok_or_else(|| format!("no job {}", id.0))
    }

    fn tick(&mut self, now: Time) {
        // Continuous-ish runtimes (podman/k8s) schedule every tick;
        // batch systems only on their scheduler pass boundary.
        match self.params.kind {
            SiteKind::Podman | SiteKind::Kubernetes => {
                self.advance_lifecycles(now);
                self.scheduler_pass(now);
            }
            SiteKind::HtCondor | SiteKind::Slurm => {
                self.advance_lifecycles(now);
                if now >= self.next_sched_pass {
                    self.scheduler_pass(now);
                }
                // FIXED cadence, consumed unconditionally: boundaries
                // advance by whole intervals from the previous boundary
                // — never from the tick that happened to observe one —
                // and they advance whether or not the pass above ran.
                // Together with the same catch-up in `create`, this
                // makes a tick with nothing to match a pure no-op:
                // skipping it cannot shift any later pass, which is
                // what lets the reactive coordinator skip quiescent
                // reconciles. For pollers whose tick grid divides the
                // interval (every driver in-tree) the boundary chain is
                // identical to the old `now + interval` behaviour.
                self.consume_boundaries(now);
            }
        }
        self.advance_lifecycles(now);
    }

    fn census(&self) -> (usize, usize) {
        let queued = self.jobs_in_state(RemoteState::Queued)
            + self.jobs_in_state(RemoteState::Starting);
        let running = self.jobs_in_state(RemoteState::Running);
        (queued, running)
    }

    fn advertised_capacity(&self) -> (u64, u64) {
        (self.params.cpu_capacity_m, self.params.mem_capacity)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offload::plugins;

    fn job(runtime: f64) -> JobDescriptor {
        JobDescriptor {
            name: "flashsim".into(),
            command: "python generate.py".into(),
            cpu_m: 1000,
            mem: 2 << 30,
            runtime_s: runtime,
            needs_shared_fs: false,
            secrets: vec![],
        }
    }

    fn drive(site: &mut SiteModel, until: Time, dt: f64) {
        let mut t = 0.0;
        while t <= until {
            site.tick(t);
            t += dt;
        }
    }

    #[test]
    fn podman_starts_immediately_and_caps_slots() {
        let mut site = plugins::podman::cloud_vm(1);
        for _ in 0..site.params.slots {
            site.create(job(100.0), 0.0).unwrap();
        }
        assert!(site.create(job(100.0), 0.0).is_err());
        // First tick matches all containers; they run after the ~3 s
        // container start (sampled ×[0.8, 1.3]).
        site.tick(1.0);
        site.tick(4.0);
        site.tick(8.0);
        assert_eq!(site.jobs_in_state(RemoteState::Running), site.params.slots);
    }

    #[test]
    fn htcondor_starts_in_negotiation_batches() {
        let mut site = plugins::htcondor::infn_tier1(2);
        for _ in 0..50 {
            site.create(job(10_000.0), 0.0).unwrap();
        }
        // Before the first negotiation pass + queue wait nothing runs.
        site.tick(1.0);
        assert_eq!(site.jobs_in_state(RemoteState::Running), 0);
        drive(&mut site, 4000.0, 10.0);
        let (_, running) = site.census();
        assert!(running > 0, "Tier-1 should be running jobs by t=4000");
    }

    #[test]
    fn slurm_backfill_favours_short_jobs() {
        let mut params = plugins::slurm::leonardo(3).params.clone();
        params.slots = 4;
        let mut site = SiteModel::new("leonardo", params, 3);
        // Long jobs with long queue waits…
        for _ in 0..4 {
            site.create(job(50_000.0), 0.0).unwrap();
        }
        // …and one short job that backfill should start early.
        let short = site.create(job(30.0), 0.0).unwrap();
        drive(&mut site, 130.0, 5.0);
        let s = site.status(short).unwrap();
        assert!(
            matches!(
                s,
                RemoteState::Starting | RemoteState::Running | RemoteState::Succeeded
            ),
            "short job should have been backfilled, is {s:?}"
        );
    }

    #[test]
    fn policy_rejects_fuse_and_secrets() {
        let mut site = plugins::htcondor::infn_tier1(4);
        assert!(!site.params.policy.allow_fuse_mounts);
        let mut j = job(10.0);
        j.needs_shared_fs = true;
        assert!(site.create(j, 0.0).is_err());
        let mut j2 = job(10.0);
        j2.secrets.push("cvmfs-key".into());
        assert!(site.create(j2, 0.0).is_err());
        assert_eq!(site.n_rejected, 2);
    }

    #[test]
    fn jobs_complete_and_counters_track() {
        let mut site = plugins::podman::cloud_vm(5);
        let id = site.create(job(50.0), 0.0).unwrap();
        drive(&mut site, 120.0, 1.0);
        assert_eq!(site.status(id), Some(RemoteState::Succeeded));
        assert_eq!(site.n_succeeded, 1);
    }

    #[test]
    fn outage_windows_refuse_creates_but_keep_jobs_draining() {
        let mut site = plugins::podman::cloud_vm(9);
        let id = site.create(job(50.0), 0.0).unwrap();
        site.add_outage(10.0, 60.0);
        assert!(!site.in_outage(9.9));
        assert!(site.in_outage(10.0));
        assert!(site.in_outage(59.9));
        assert!(!site.in_outage(60.0), "window is half-open");
        let rejected_before = site.n_rejected;
        assert!(site.create(job(10.0), 30.0).is_err());
        assert_eq!(site.n_rejected, rejected_before + 1);
        // The already-created job drains right through the outage.
        drive(&mut site, 120.0, 1.0);
        assert_eq!(site.status(id), Some(RemoteState::Succeeded));
        // After the window, creates flow again.
        assert!(site.create(job(10.0), 60.0).is_ok());
    }

    /// The outage gate sits before every RNG draw: a run whose creates
    /// were all refused by outages leaves the site's stream exactly
    /// where it started, so post-outage jobs sample identically to a
    /// run where the refused creates never happened.
    #[test]
    fn outage_refusals_do_not_touch_the_rng_stream() {
        let mk = |with_refusals: bool| {
            let mut site = plugins::slurm::leonardo(11);
            site.add_outage(0.0, 100.0);
            if with_refusals {
                for _ in 0..5 {
                    assert!(site.create(job(600.0), 50.0).is_err());
                }
            }
            let id = site.create(job(600.0), 100.0).unwrap();
            let mut t = 100.0;
            while t < 4000.0 {
                site.tick(t);
                t += 10.0;
            }
            site.status(id).unwrap()
        };
        assert_eq!(mk(true), mk(false));
    }

    #[test]
    fn delete_cancels() {
        let mut site = plugins::kubernetes::recas_tier2(6);
        let id = site.create(job(1000.0), 0.0).unwrap();
        site.delete(id).unwrap();
        assert_eq!(site.status(id), None);
        assert!(site.delete(id).is_err());
    }

    #[test]
    fn same_seed_same_trace() {
        let run = |seed| {
            let mut site = plugins::slurm::leonardo(seed);
            let mut running = Vec::new();
            for i in 0..100 {
                site.create(job(600.0), 0.0).unwrap();
                let _ = i;
            }
            let mut t = 0.0;
            while t < 2000.0 {
                site.tick(t);
                running.push(site.census().1);
                t += 30.0;
            }
            running
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
    }
}
