//! Offloading: scale the applications beyond cluster boundaries (§4).
//!
//! The architecture mirrors the paper's Figure 1 layering exactly:
//!
//! ```text
//!  Kueue ──admits──▶ virtual node (cluster::Node { virtual_node })
//!                      │  Virtual Kubelet facade
//!                      ▼
//!                [`vnode::VirtualNodeController`]
//!                      │  interLink REST-ish API
//!                      ▼
//!                [`interlink::InterLinkPlugin`] (trait)
//!                      │
//!        ┌─────────────┼──────────────┬─────────────┐
//!        ▼             ▼              ▼             ▼
//!    HTCondor        Slurm         Podman       Kubernetes
//!   (INFN-Tier1)  (Leonardo,     (cloud VM)    (recas Tier-2,
//!                  Terabit-PD)                  §4 "soon")
//! ```
//!
//! Each site plugin is a queueing model with the scheduler semantics of
//! its batch system (negotiation cycles, backfill, instant container
//! start, …) and site-calibrated delay/capacity parameters — these
//! dynamics are what give Figure 2 its shape.

pub mod interlink;
pub mod plugins;
pub mod sites;
pub mod vnode;

pub use interlink::{InterLinkPlugin, RemoteJobId, RemoteState};
pub use sites::{SiteKind, SiteModel, SitePolicy};
pub use vnode::{Breaker, BreakerState, RetryPolicy, VirtualNodeController};
