//! The interLink plugin API (§4).
//!
//! "A further abstraction layer defining a simplified set of REST APIs
//! that can be implemented by the so-called InterLink plugins providing
//! the actual access to the compute resources."
//!
//! The trait is the REST surface (create/status/logs/delete) plus the
//! simulation hooks (`tick`, capacity introspection) the virtual-node
//! controller uses. Implementations live in [`super::sites`] /
//! [`super::plugins`].

use crate::sim::Time;

/// Remote job handle returned by `create`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RemoteJobId(pub u64);

/// Remote lifecycle as reported through the plugin status API.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RemoteState {
    /// Accepted by the site's batch system, waiting in its queue.
    Queued,
    /// Resources matched; container/image being set up.
    Starting,
    Running,
    Succeeded,
    Failed,
}

impl RemoteState {
    pub fn is_terminal(&self) -> bool {
        matches!(self, RemoteState::Succeeded | RemoteState::Failed)
    }
}

/// What the virtual kubelet ships to the plugin: enough of the pod spec
/// to run it remotely. Secrets are injected by vkd, never by users (§4).
#[derive(Clone, Debug)]
pub struct JobDescriptor {
    pub name: String,
    pub command: String,
    pub cpu_m: u64,
    pub mem: u64,
    /// Runtime the site will realise (sampled by the workload model).
    pub runtime_s: f64,
    /// Requires mounting the shared JuiceFS (§4: only if site policy
    /// allows FUSE).
    pub needs_shared_fs: bool,
    /// Secret names shipped with the job (site policy may forbid).
    pub secrets: Vec<String>,
}

/// The interLink plugin interface. One instance per site.
pub trait InterLinkPlugin: std::fmt::Debug {
    /// Site key (the Fig. 2 legend label, e.g. "leonardo").
    fn name(&self) -> &str;

    /// REST: submit. Returns Err when the site refuses (policy, full
    /// non-queueing runtime, …).
    fn create(&mut self, job: JobDescriptor, now: Time) -> Result<RemoteJobId, String>;

    /// REST: status probe.
    fn status(&self, id: RemoteJobId) -> Option<RemoteState>;

    /// REST: logs (diagnostic line for the demo CLI).
    fn logs(&self, id: RemoteJobId) -> String;

    /// REST: delete/cancel.
    fn delete(&mut self, id: RemoteJobId) -> Result<(), String>;

    /// Advance the site's internal queueing model to `now`.
    fn tick(&mut self, now: Time);

    /// Jobs currently in each state (queued, starting+running) — the
    /// Fig. 2 observable.
    fn census(&self) -> (usize, usize);

    /// Advertised capacity for the virtual node (cpu millicores, mem).
    fn advertised_capacity(&self) -> (u64, u64);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn remote_state_terminality() {
        assert!(RemoteState::Succeeded.is_terminal());
        assert!(RemoteState::Failed.is_terminal());
        assert!(!RemoteState::Queued.is_terminal());
        assert!(!RemoteState::Running.is_terminal());
    }
}
