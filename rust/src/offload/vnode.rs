//! Virtual-node controller: the Virtual Kubelet facade (§4).
//!
//! "Virtual nodes are Kubernetes nodes that are not backed by a Linux
//! kernel but mimic a Kubernetes kubelet in the interactions with the
//! Kubernetes API server. ... The AI_INFN platform relies on the
//! InterLink provider."
//!
//! For every site plugin the controller registers a `vk-<site>` node
//! whose capacity is the plugin's advertised capacity. When Kueue binds
//! an offload-compatible pod to that node, the controller translates the
//! pod into a [`JobDescriptor`], ships it through the plugin's create
//! API, then reconciles remote status back onto the pod (Succeeded /
//! Failed / retry-on-refusal).

use std::collections::BTreeMap;

use super::interlink::{InterLinkPlugin, JobDescriptor, RemoteJobId, RemoteState};
use super::sites::SiteModel;
use crate::cluster::{Cluster, Node, PodId, PodPhase};
use crate::sim::Time;

/// A pod's remote incarnation.
#[derive(Clone, Debug)]
pub struct RemoteBinding {
    pub pod: PodId,
    pub site: String,
    pub job: RemoteJobId,
}

#[derive(Debug, Default)]
pub struct VirtualNodeController {
    sites: BTreeMap<String, SiteModel>,
    bindings: BTreeMap<PodId, RemoteBinding>,
    /// Pods bound to a vnode whose create() was refused (podman-full,
    /// policy) — retried each reconcile.
    retry: Vec<PodId>,
    /// Completed remote jobs per site (experiment counters).
    pub completed_per_site: BTreeMap<String, u64>,
    /// Edge signal for the reactive coordinator: set whenever remote
    /// state changed outside a reconcile (a launch landed a new job or
    /// queued a retry; a site was registered) — the transitions after
    /// which the next reconcile instant must be recomputed. Consumed by
    /// [`VirtualNodeController::take_dirty`].
    dirty: bool,
}

impl VirtualNodeController {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a site plugin and its virtual node in the cluster. The
    /// node lands in the cluster's [`crate::cluster::NodeIndex`] virtual
    /// set, which is how Kueue's offload path finds the federation's
    /// handful of sites without scanning the whole farm.
    ///
    /// Site policy is advertised as node taints so routing happens at
    /// scheduling time instead of failing forever at create time: a
    /// site that forbids FUSE mounts taints its virtual node with
    /// `interlink.no-fuse` — vkd gives the matching toleration only to
    /// jobs that do NOT need the shared file system (§4's
    /// "if allowed by site-specific policies").
    pub fn register_site(&mut self, cluster: &mut Cluster, site: SiteModel) {
        let (cpu_m, mem) = site.advertised_capacity();
        let node_name = format!("vk-{}", site.name);
        let mut node = Node::virtual_node(&node_name, &site.name, cpu_m, mem);
        if !site.params.policy.allow_fuse_mounts {
            node = node.with_taint("interlink.no-fuse");
        }
        cluster.add_node(node);
        self.sites.insert(site.name.clone(), site);
        self.dirty = true;
    }

    /// Consume the remote-state edge signal (see the `dirty` field).
    pub fn take_dirty(&mut self) -> bool {
        std::mem::take(&mut self.dirty)
    }

    /// Earliest future instant at which a reconcile could observe or
    /// cause a state change: the minimum of every site's
    /// [`SiteModel::next_transition_after`], or `now` itself while
    /// refused creates are waiting to be retried (retries happen once
    /// per reconcile, so the retry cadence is the caller's wakeup
    /// cadence). `None` means the whole federation is quiescent and a
    /// reconcile before the next launch would be a no-op.
    pub fn next_transition_after(&self, now: Time) -> Option<Time> {
        let mut next = if self.retry.is_empty() {
            f64::INFINITY
        } else {
            now
        };
        for site in self.sites.values() {
            if let Some(t) = site.next_transition_after(now) {
                next = next.min(t);
            }
        }
        next.is_finite().then_some(next)
    }

    pub fn site(&self, name: &str) -> Option<&SiteModel> {
        self.sites.get(name)
    }

    pub fn site_mut(&mut self, name: &str) -> Option<&mut SiteModel> {
        self.sites.get_mut(name)
    }

    pub fn sites(&self) -> impl Iterator<Item = &SiteModel> {
        self.sites.values()
    }

    pub fn binding(&self, pod: PodId) -> Option<&RemoteBinding> {
        self.bindings.get(&pod)
    }

    fn descriptor_for(cluster: &Cluster, pod: PodId) -> Option<JobDescriptor> {
        let p = cluster.pod(pod)?;
        Some(JobDescriptor {
            name: format!("{}", pod),
            command: p.spec.command.clone(),
            cpu_m: p.spec.resources.cpu_m,
            mem: p.spec.resources.mem,
            runtime_s: p.spec.est_runtime_s,
            needs_shared_fs: p.spec.volumes.iter().any(|v| v == "juicefs"),
            secrets: Vec::new(), // vkd strips secrets for offloaded jobs
        })
    }

    /// Called when Kueue has bound `pod` to virtual node `vk-<site>`:
    /// ship it through interLink.
    pub fn launch(
        &mut self,
        cluster: &Cluster,
        pod: PodId,
        site_name: &str,
        now: Time,
    ) -> Result<RemoteJobId, String> {
        let desc = Self::descriptor_for(cluster, pod)
            .ok_or_else(|| format!("pod {pod} not found"))?;
        let site = self
            .sites
            .get_mut(site_name)
            .ok_or_else(|| format!("no site {site_name}"))?;
        match site.create(desc, now) {
            Ok(job) => {
                self.bindings.insert(
                    pod,
                    RemoteBinding { pod, site: site_name.to_string(), job },
                );
                self.dirty = true;
                Ok(job)
            }
            Err(e) => {
                self.retry.push(pod);
                self.dirty = true;
                Err(e)
            }
        }
    }

    /// One reconcile pass: advance every site model, reflect terminal
    /// remote states onto cluster pods, retry refused creates. Returns
    /// pods that reached a terminal state this pass.
    pub fn reconcile(
        &mut self,
        cluster: &mut Cluster,
        now: Time,
    ) -> Vec<(PodId, RemoteState)> {
        for site in self.sites.values_mut() {
            site.tick(now);
        }

        // Retry refused creates (podman-full case).
        let retry: Vec<PodId> = std::mem::take(&mut self.retry);
        for pod in retry {
            let backend = cluster
                .pod(pod)
                .and_then(|p| p.node)
                .and_then(|nid| cluster.node_by_id(nid))
                .and_then(|n| n.backend.clone());
            if let Some(backend) = backend {
                let _ = self.launch(cluster, pod, &backend, now);
            }
        }

        let mut terminal = Vec::new();
        let mut done_bindings = Vec::new();
        for (pod, b) in &self.bindings {
            let state = self.sites[&b.site].status(b.job);
            if let Some(s) = state {
                if s.is_terminal() {
                    terminal.push((*pod, s));
                    done_bindings.push(*pod);
                }
            }
        }
        for (pod, state) in &terminal {
            if cluster.pod(*pod).map(|p| p.phase) == Some(PodPhase::Running) {
                match state {
                    RemoteState::Succeeded => {
                        let _ = cluster.complete(*pod);
                    }
                    RemoteState::Failed => {
                        let _ = cluster.fail(*pod);
                    }
                    _ => unreachable!(),
                }
            }
            if let Some(b) = self.bindings.get(pod) {
                // get_mut-first: the site-name String is cloned only
                // the first time a site completes a job, not once per
                // completion (this runs for every finished remote job).
                match self.completed_per_site.get_mut(&b.site) {
                    Some(n) => *n += 1,
                    None => {
                        self.completed_per_site.insert(b.site.clone(), 1);
                    }
                }
            }
        }
        for pod in done_bindings {
            self.bindings.remove(&pod);
        }
        terminal
    }

    /// Fig. 2 observable: running remote jobs per site.
    pub fn running_per_site(&self) -> BTreeMap<String, usize> {
        self.sites
            .iter()
            .map(|(name, s)| (name.clone(), s.census().1))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{PodSpec, Resources, Scheduler, ScoringPolicy};
    use crate::offload::plugins;

    fn offload_spec(runtime: f64) -> PodSpec {
        let mut spec = PodSpec::batch("rosa", Resources::flashsim_cpu(), "flashsim");
        spec.offload_compatible = true;
        spec.tolerations.push("interlink.virtual-node".into());
        spec.est_runtime_s = runtime;
        spec
    }

    fn setup() -> (Cluster, VirtualNodeController, Scheduler) {
        let mut cluster = Cluster::new();
        let mut vk = VirtualNodeController::new();
        vk.register_site(&mut cluster, plugins::podman::cloud_vm(1));
        vk.register_site(&mut cluster, plugins::slurm::terabit_padova(2));
        (cluster, vk, Scheduler::new())
    }

    #[test]
    fn register_creates_virtual_nodes() {
        let (cluster, vk, _) = setup();
        assert!(cluster.node("vk-podman").unwrap().virtual_node);
        assert!(cluster.node("vk-terabitpadova").is_some());
        assert_eq!(vk.sites().count(), 2);
    }

    #[test]
    fn registered_sites_populate_the_virtual_index() {
        let (cluster, _, _) = setup();
        let indexed: Vec<&str> = cluster
            .index()
            .virtual_nodes()
            .map(|id| cluster.name_of(id))
            .collect();
        assert_eq!(indexed, vec!["vk-podman", "vk-terabitpadova"]);
        // Virtual nodes never leak into the physical CPU-headroom index.
        assert!(cluster
            .index()
            .physical_with_cpu(0)
            .all(|id| !cluster.name_of(id).starts_with("vk-")));
    }

    #[test]
    fn launch_reconcile_complete_roundtrip() {
        let (mut cluster, mut vk, s) = setup();
        let pod = cluster.create_pod(offload_spec(30.0));
        // Bind to the podman vnode and launch.
        let node = s.schedule(&mut cluster, pod, ScoringPolicy::Spread).unwrap();
        assert!(cluster.name_of(node).starts_with("vk-"));
        let backend =
            cluster.node_by_id(node).unwrap().backend.clone().unwrap();
        vk.launch(&cluster, pod, &backend, 0.0).unwrap();
        // Drive time forward.
        let mut t = 0.0;
        let mut finished = Vec::new();
        while t < 300.0 && finished.is_empty() {
            t += 5.0;
            finished = vk.reconcile(&mut cluster, t);
        }
        assert_eq!(finished.len(), 1);
        assert_eq!(finished[0].1, RemoteState::Succeeded);
        assert_eq!(
            cluster.pod(pod).unwrap().phase,
            PodPhase::Succeeded
        );
        assert_eq!(vk.completed_per_site.get(&backend), Some(&1));
    }

    #[test]
    fn refused_create_is_retried_until_slot_frees() {
        let (mut cluster, mut vk, s) = setup();
        // Saturate podman's 8 slots with half-core jobs: the virtual
        // node's CPU capacity fits all 9 pods, but the container
        // runtime's 8 slots do not — the 9th create is refused at the
        // interLink layer and must be retried.
        let mut pods = Vec::new();
        for _ in 0..9 {
            let mut spec = offload_spec(40.0);
            spec.resources.cpu_m = 500;
            spec.node_selector = Some("vk-podman".into());
            let p = cluster.create_pod(spec);
            s.schedule(&mut cluster, p, ScoringPolicy::Spread).unwrap();
            pods.push(p);
        }
        let mut refused = 0;
        for &p in &pods {
            if vk.launch(&cluster, p, "podman", 0.0).is_err() {
                refused += 1;
            }
        }
        assert_eq!(refused, 1, "9th container refused on an 8-slot VM");
        // After the first batch completes, the retry lands.
        let mut t = 0.0;
        while t < 600.0 {
            t += 5.0;
            vk.reconcile(&mut cluster, t);
        }
        let done = pods
            .iter()
            .filter(|p| cluster.pod(**p).unwrap().phase == PodPhase::Succeeded)
            .count();
        assert_eq!(done, 9, "all jobs complete after retry");
    }

    #[test]
    fn running_per_site_census() {
        let (mut cluster, mut vk, s) = setup();
        for _ in 0..4 {
            let mut spec = offload_spec(500.0);
            spec.node_selector = Some("vk-podman".into());
            let p = cluster.create_pod(spec);
            s.schedule(&mut cluster, p, ScoringPolicy::Spread).unwrap();
            vk.launch(&cluster, p, "podman", 0.0).unwrap();
        }
        vk.reconcile(&mut cluster, 10.0);
        vk.reconcile(&mut cluster, 20.0);
        let census = vk.running_per_site();
        assert_eq!(census.get("podman"), Some(&4));
        assert_eq!(census.get("terabitpadova"), Some(&0));
    }
}
