//! Virtual-node controller: the Virtual Kubelet facade (§4).
//!
//! "Virtual nodes are Kubernetes nodes that are not backed by a Linux
//! kernel but mimic a Kubernetes kubelet in the interactions with the
//! Kubernetes API server. ... The AI_INFN platform relies on the
//! InterLink provider."
//!
//! For every site plugin the controller registers a `vk-<site>` node
//! whose capacity is the plugin's advertised capacity. When Kueue binds
//! an offload-compatible pod to that node, the controller translates the
//! pod into a [`JobDescriptor`], ships it through the plugin's create
//! API, then reconciles remote status back onto the pod (Succeeded /
//! Failed / retry-on-refusal).

use std::collections::BTreeMap;

use super::interlink::{InterLinkPlugin, JobDescriptor, RemoteJobId, RemoteState};
use super::sites::SiteModel;
use crate::cluster::{Cluster, Node, PodId, PodPhase};
use crate::sim::Time;

/// A pod's remote incarnation.
#[derive(Clone, Debug)]
pub struct RemoteBinding {
    pub pod: PodId,
    pub site: String,
    pub job: RemoteJobId,
}

/// Create-retry and circuit-breaker knobs — the site-facing half of
/// the chaos recovery layer (see the `chaos` module docs). Defaults
/// are loose enough that a transient podman-slot refusal still lands
/// well within budget, and tight enough that a dead site cannot absorb
/// unbounded create traffic.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// First retry delay after a refused create; doubles per attempt.
    /// Raw deadlines — they take effect at the first reconcile instant
    /// at or after them, identically in both loop modes (the
    /// backoff-on-grid rule).
    pub base_s: f64,
    /// Max create attempts per pod (the initial launch included)
    /// before it goes terminal-Failed with a stamped reason.
    pub budget: u32,
    /// Consecutive create failures that open a site's breaker.
    pub breaker_threshold: u32,
    /// First open window; doubles per re-open, capped at the max.
    pub breaker_open_base_s: f64,
    pub breaker_open_max_s: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            base_s: 10.0,
            budget: 6,
            breaker_threshold: 3,
            breaker_open_base_s: 20.0,
            breaker_open_max_s: 160.0,
        }
    }
}

/// Observable breaker state at an instant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: creates flow to the site.
    Closed,
    /// Tripped: creates are refused *before* reaching the site (and
    /// before any of its RNG draws) until the open window passes.
    Open,
    /// The open window passed: the next create is the probe — success
    /// closes the breaker, failure re-opens it with a doubled window.
    HalfOpen,
}

/// Per-site health tracker. The state is a **pure function of the
/// stored health window and the query instant** ([`Breaker::state_at`])
/// — there is no open→half-open transition *event*, so both loop modes
/// reading at the same instants compute the same answer regardless of
/// their wakeup cadence.
#[derive(Clone, Copy, Debug, Default)]
pub struct Breaker {
    /// Consecutive create failures (any success resets).
    pub consecutive_failures: u32,
    /// While `Some(u)`: Open before `u`, HalfOpen at/after it.
    pub open_until: Option<Time>,
    /// Times opened since the last success (drives the exponential
    /// open window).
    pub opens: u32,
}

impl Breaker {
    pub fn state_at(&self, now: Time) -> BreakerState {
        match self.open_until {
            None => BreakerState::Closed,
            Some(u) if now < u => BreakerState::Open,
            Some(_) => BreakerState::HalfOpen,
        }
    }

    /// Whether a create may proceed at `now` (Closed, or the HalfOpen
    /// probe).
    pub fn allows(&self, now: Time) -> bool {
        self.state_at(now) != BreakerState::Open
    }

    fn on_failure(&mut self, now: Time, policy: &RetryPolicy) {
        let failed_probe = self.state_at(now) == BreakerState::HalfOpen;
        self.consecutive_failures += 1;
        if failed_probe
            || (self.open_until.is_none()
                && self.consecutive_failures >= policy.breaker_threshold)
        {
            let k = self.opens.min(16);
            self.opens += 1;
            let window = (policy.breaker_open_base_s * (1u64 << k) as f64)
                .min(policy.breaker_open_max_s);
            self.open_until = Some(now + window);
        }
    }

    fn on_success(&mut self) {
        self.consecutive_failures = 0;
        self.open_until = None;
        self.opens = 0;
    }
}

/// A pod on the create-retry ladder.
#[derive(Clone, Copy, Debug)]
struct RetryEntry {
    pod: PodId,
    /// Actual `site.create` attempts so far (breaker fail-fasts do not
    /// count — they never reached the site).
    attempts: u32,
    next_at: Time,
}

/// Outcome of one create attempt (internal).
enum CreateOutcome {
    Launched(RemoteJobId),
    /// The site's breaker refused before the site saw the request;
    /// retry no earlier than the carried half-open instant.
    BreakerOpen(Time),
    /// The site itself refused (slots full, policy, outage window).
    Refused(String),
}

#[derive(Debug, Default)]
pub struct VirtualNodeController {
    sites: BTreeMap<String, SiteModel>,
    bindings: BTreeMap<PodId, RemoteBinding>,
    /// Pods bound to a vnode whose create() was refused (podman-full,
    /// policy, outage, open breaker) — retried with exponential
    /// backoff, bounded by [`RetryPolicy::budget`].
    retry: Vec<RetryEntry>,
    /// Per-site health trackers (created on first create attempt).
    breakers: BTreeMap<String, Breaker>,
    pub policy: RetryPolicy,
    /// Pods whose create-retry budget ran out (terminal-Failed with a
    /// stamped reason).
    pub n_retry_exhausted: u64,
    /// Creates fail-fasted by an open breaker (never reached the site).
    pub n_breaker_refusals: u64,
    /// Completed remote jobs per site (experiment counters).
    pub completed_per_site: BTreeMap<String, u64>,
    /// Edge signal for the reactive coordinator: set whenever remote
    /// state changed outside a reconcile (a launch landed a new job or
    /// queued a retry; a site was registered) — the transitions after
    /// which the next reconcile instant must be recomputed. Consumed by
    /// [`VirtualNodeController::take_dirty`].
    dirty: bool,
}

impl VirtualNodeController {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a site plugin and its virtual node in the cluster. The
    /// node lands in the cluster's [`crate::cluster::NodeIndex`] virtual
    /// set, which is how Kueue's offload path finds the federation's
    /// handful of sites without scanning the whole farm.
    ///
    /// Site policy is advertised as node taints so routing happens at
    /// scheduling time instead of failing forever at create time: a
    /// site that forbids FUSE mounts taints its virtual node with
    /// `interlink.no-fuse` — vkd gives the matching toleration only to
    /// jobs that do NOT need the shared file system (§4's
    /// "if allowed by site-specific policies").
    pub fn register_site(&mut self, cluster: &mut Cluster, site: SiteModel) {
        let (cpu_m, mem) = site.advertised_capacity();
        let node_name = format!("vk-{}", site.name);
        let mut node = Node::virtual_node(&node_name, &site.name, cpu_m, mem);
        if !site.params.policy.allow_fuse_mounts {
            node = node.with_taint("interlink.no-fuse");
        }
        cluster.add_node(node);
        self.sites.insert(site.name.clone(), site);
        self.dirty = true;
    }

    /// Consume the remote-state edge signal (see the `dirty` field).
    pub fn take_dirty(&mut self) -> bool {
        std::mem::take(&mut self.dirty)
    }

    /// Earliest future instant at which a reconcile could observe or
    /// cause a state change: the minimum of every site's
    /// [`SiteModel::next_transition_after`] and every retry entry's
    /// backoff deadline (clamped to `now` — a due entry retries at the
    /// caller's next wakeup, so the effective retry instants land on
    /// the reconcile grid in both loop modes). `None` means the whole
    /// federation is quiescent and a reconcile before the next launch
    /// would be a no-op.
    pub fn next_transition_after(&self, now: Time) -> Option<Time> {
        let mut next = f64::INFINITY;
        for e in &self.retry {
            next = next.min(e.next_at.max(now));
        }
        for site in self.sites.values() {
            if let Some(t) = site.next_transition_after(now) {
                next = next.min(t);
            }
        }
        next.is_finite().then_some(next)
    }

    /// The health tracker of `site` (a fresh Closed breaker if no
    /// create ever touched it). Copy-out keeps transitions internal.
    pub fn breaker(&self, site: &str) -> Breaker {
        self.breakers.get(site).copied().unwrap_or_default()
    }

    /// Pods currently waiting on the create-retry ladder.
    pub fn retry_backlog(&self) -> usize {
        self.retry.len()
    }

    pub fn site(&self, name: &str) -> Option<&SiteModel> {
        self.sites.get(name)
    }

    pub fn site_mut(&mut self, name: &str) -> Option<&mut SiteModel> {
        self.sites.get_mut(name)
    }

    pub fn sites(&self) -> impl Iterator<Item = &SiteModel> {
        self.sites.values()
    }

    pub fn binding(&self, pod: PodId) -> Option<&RemoteBinding> {
        self.bindings.get(&pod)
    }

    fn descriptor_for(cluster: &Cluster, pod: PodId) -> Option<JobDescriptor> {
        let p = cluster.pod(pod)?;
        Some(JobDescriptor {
            name: format!("{}", pod),
            command: p.spec.command.clone(),
            cpu_m: p.spec.resources.cpu_m,
            mem: p.spec.resources.mem,
            runtime_s: p.spec.est_runtime_s,
            needs_shared_fs: p.spec.volumes.iter().any(|v| v == "juicefs"),
            secrets: Vec::new(), // vkd strips secrets for offloaded jobs
        })
    }

    /// One create attempt against a site, breaker-gated. A breaker
    /// fail-fast happens *before* `SiteModel::create` — the site sees
    /// no request and draws no RNG, so breaker decisions (identical
    /// across loop modes, since attempt instants are) cannot skew any
    /// random stream.
    fn try_create(
        &mut self,
        cluster: &Cluster,
        pod: PodId,
        site_name: &str,
        now: Time,
    ) -> CreateOutcome {
        let desc = match Self::descriptor_for(cluster, pod) {
            Some(d) => d,
            None => return CreateOutcome::Refused(format!("pod {pod} not found")),
        };
        let br = *self.breakers.entry(site_name.to_string()).or_default();
        if !br.allows(now) {
            self.n_breaker_refusals += 1;
            return CreateOutcome::BreakerOpen(br.open_until.unwrap());
        }
        let site = match self.sites.get_mut(site_name) {
            Some(s) => s,
            None => {
                return CreateOutcome::Refused(format!("no site {site_name}"))
            }
        };
        match site.create(desc, now) {
            Ok(job) => {
                self.breakers.get_mut(site_name).unwrap().on_success();
                self.bindings.insert(
                    pod,
                    RemoteBinding { pod, site: site_name.to_string(), job },
                );
                self.dirty = true;
                CreateOutcome::Launched(job)
            }
            Err(e) => {
                let policy = self.policy;
                self.breakers
                    .get_mut(site_name)
                    .unwrap()
                    .on_failure(now, &policy);
                CreateOutcome::Refused(e)
            }
        }
    }

    /// Called when Kueue has bound `pod` to virtual node `vk-<site>`:
    /// ship it through interLink. A refusal (site or breaker) queues
    /// the pod on the bounded retry ladder.
    pub fn launch(
        &mut self,
        cluster: &Cluster,
        pod: PodId,
        site_name: &str,
        now: Time,
    ) -> Result<RemoteJobId, String> {
        match self.try_create(cluster, pod, site_name, now) {
            CreateOutcome::Launched(job) => Ok(job),
            CreateOutcome::BreakerOpen(until) => {
                self.retry.push(RetryEntry {
                    pod,
                    attempts: 0,
                    next_at: until,
                });
                self.dirty = true;
                Err(format!("site {site_name}: circuit breaker open"))
            }
            CreateOutcome::Refused(e) => {
                self.retry.push(RetryEntry {
                    pod,
                    attempts: 1,
                    next_at: now + self.policy.base_s,
                });
                self.dirty = true;
                Err(e)
            }
        }
    }

    /// One reconcile pass: advance every site model, reflect terminal
    /// remote states onto cluster pods, retry refused creates. Returns
    /// pods that reached a terminal state this pass.
    pub fn reconcile(
        &mut self,
        cluster: &mut Cluster,
        now: Time,
    ) -> Vec<(PodId, RemoteState)> {
        for site in self.sites.values_mut() {
            site.tick(now);
        }

        // Walk the retry ladder: due entries attempt a create (the
        // first due entry against a half-open site is the probe);
        // refused entries climb the exponential ladder until the
        // budget runs out; breaker fail-fasts wait for the half-open
        // instant without consuming budget.
        let mut exhausted: Vec<PodId> = Vec::new();
        let ladder: Vec<RetryEntry> = std::mem::take(&mut self.retry);
        for e in ladder {
            if e.next_at > now {
                self.retry.push(e);
                continue;
            }
            let backend = cluster
                .pod(e.pod)
                .and_then(|p| p.node)
                .and_then(|nid| cluster.node_by_id(nid))
                .and_then(|n| n.backend.clone());
            let backend = match backend {
                Some(b) => b,
                None => continue, // pod unbound or gone: drop the entry
            };
            match self.try_create(cluster, e.pod, &backend, now) {
                CreateOutcome::Launched(_) => {}
                CreateOutcome::BreakerOpen(until) => {
                    self.retry.push(RetryEntry { next_at: until, ..e });
                }
                CreateOutcome::Refused(_) => {
                    let attempts = e.attempts + 1;
                    if attempts >= self.policy.budget {
                        exhausted.push(e.pod);
                    } else {
                        let k = attempts.min(16);
                        self.retry.push(RetryEntry {
                            pod: e.pod,
                            attempts,
                            next_at: now
                                + self.policy.base_s
                                    * (1u64 << (k - 1)) as f64,
                        });
                    }
                }
            }
        }

        let mut terminal = Vec::new();
        let mut done_bindings = Vec::new();
        for (pod, b) in &self.bindings {
            let state = self.sites[&b.site].status(b.job);
            if let Some(s) = state {
                if s.is_terminal() {
                    terminal.push((*pod, s));
                    done_bindings.push(*pod);
                }
            }
        }
        for (pod, state) in &terminal {
            if cluster.pod(*pod).map(|p| p.phase) == Some(PodPhase::Running) {
                match state {
                    RemoteState::Succeeded => {
                        let _ = cluster.complete(*pod);
                    }
                    RemoteState::Failed => {
                        let _ = cluster.fail(*pod);
                    }
                    _ => unreachable!(),
                }
            }
            if let Some(b) = self.bindings.get(pod) {
                // get_mut-first: the site-name String is cloned only
                // the first time a site completes a job, not once per
                // completion (this runs for every finished remote job).
                match self.completed_per_site.get_mut(&b.site) {
                    Some(n) => *n += 1,
                    None => {
                        self.completed_per_site.insert(b.site.clone(), 1);
                    }
                }
            }
        }
        for pod in done_bindings {
            self.bindings.remove(&pod);
        }
        // Budget-exhausted pods go terminal-Failed with the reason
        // stamped, and surface in the terminal list so the coordinator
        // finishes their Kueue workloads like any remote failure.
        for pod in exhausted {
            self.n_retry_exhausted += 1;
            if cluster.pod(pod).map(|p| p.phase) == Some(PodPhase::Running) {
                let _ = cluster.fail(pod);
            }
            if let Some(p) = cluster.pod_mut(pod) {
                p.failure_reason =
                    Some("virtual node create retries exhausted".to_string());
            }
            terminal.push((pod, RemoteState::Failed));
        }
        terminal
    }

    /// Fig. 2 observable: running remote jobs per site.
    pub fn running_per_site(&self) -> BTreeMap<String, usize> {
        self.sites
            .iter()
            .map(|(name, s)| (name.clone(), s.census().1))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{PodSpec, Resources, Scheduler, ScoringPolicy};
    use crate::offload::plugins;

    fn offload_spec(runtime: f64) -> PodSpec {
        let mut spec = PodSpec::batch("rosa", Resources::flashsim_cpu(), "flashsim");
        spec.offload_compatible = true;
        spec.tolerations.push("interlink.virtual-node".into());
        spec.est_runtime_s = runtime;
        spec
    }

    fn setup() -> (Cluster, VirtualNodeController, Scheduler) {
        let mut cluster = Cluster::new();
        let mut vk = VirtualNodeController::new();
        vk.register_site(&mut cluster, plugins::podman::cloud_vm(1));
        vk.register_site(&mut cluster, plugins::slurm::terabit_padova(2));
        (cluster, vk, Scheduler::new())
    }

    #[test]
    fn register_creates_virtual_nodes() {
        let (cluster, vk, _) = setup();
        assert!(cluster.node("vk-podman").unwrap().virtual_node);
        assert!(cluster.node("vk-terabitpadova").is_some());
        assert_eq!(vk.sites().count(), 2);
    }

    #[test]
    fn registered_sites_populate_the_virtual_index() {
        let (cluster, _, _) = setup();
        let indexed: Vec<&str> = cluster
            .index()
            .virtual_nodes()
            .map(|id| cluster.name_of(id))
            .collect();
        assert_eq!(indexed, vec!["vk-podman", "vk-terabitpadova"]);
        // Virtual nodes never leak into the physical CPU-headroom index.
        assert!(cluster
            .index()
            .physical_with_cpu(0)
            .all(|id| !cluster.name_of(id).starts_with("vk-")));
    }

    #[test]
    fn launch_reconcile_complete_roundtrip() {
        let (mut cluster, mut vk, s) = setup();
        let pod = cluster.create_pod(offload_spec(30.0));
        // Bind to the podman vnode and launch.
        let node = s.schedule(&mut cluster, pod, ScoringPolicy::Spread).unwrap();
        assert!(cluster.name_of(node).starts_with("vk-"));
        let backend =
            cluster.node_by_id(node).unwrap().backend.clone().unwrap();
        vk.launch(&cluster, pod, &backend, 0.0).unwrap();
        // Drive time forward.
        let mut t = 0.0;
        let mut finished = Vec::new();
        while t < 300.0 && finished.is_empty() {
            t += 5.0;
            finished = vk.reconcile(&mut cluster, t);
        }
        assert_eq!(finished.len(), 1);
        assert_eq!(finished[0].1, RemoteState::Succeeded);
        assert_eq!(
            cluster.pod(pod).unwrap().phase,
            PodPhase::Succeeded
        );
        assert_eq!(vk.completed_per_site.get(&backend), Some(&1));
    }

    #[test]
    fn refused_create_is_retried_until_slot_frees() {
        let (mut cluster, mut vk, s) = setup();
        // Saturate podman's 8 slots with half-core jobs: the virtual
        // node's CPU capacity fits all 9 pods, but the container
        // runtime's 8 slots do not — the 9th create is refused at the
        // interLink layer and must be retried.
        let mut pods = Vec::new();
        for _ in 0..9 {
            let mut spec = offload_spec(40.0);
            spec.resources.cpu_m = 500;
            spec.node_selector = Some("vk-podman".into());
            let p = cluster.create_pod(spec);
            s.schedule(&mut cluster, p, ScoringPolicy::Spread).unwrap();
            pods.push(p);
        }
        let mut refused = 0;
        for &p in &pods {
            if vk.launch(&cluster, p, "podman", 0.0).is_err() {
                refused += 1;
            }
        }
        assert_eq!(refused, 1, "9th container refused on an 8-slot VM");
        // After the first batch completes, the retry lands.
        let mut t = 0.0;
        while t < 600.0 {
            t += 5.0;
            vk.reconcile(&mut cluster, t);
        }
        let done = pods
            .iter()
            .filter(|p| cluster.pod(**p).unwrap().phase == PodPhase::Succeeded)
            .count();
        assert_eq!(done, 9, "all jobs complete after retry");
    }

    #[test]
    fn breaker_state_is_a_pure_function_of_the_window() {
        let b = Breaker {
            consecutive_failures: 3,
            open_until: Some(50.0),
            opens: 1,
        };
        assert_eq!(b.state_at(0.0), BreakerState::Open);
        assert_eq!(b.state_at(49.999), BreakerState::Open);
        assert_eq!(b.state_at(50.0), BreakerState::HalfOpen);
        assert_eq!(b.state_at(9999.0), BreakerState::HalfOpen);
        assert!(!b.allows(10.0));
        assert!(b.allows(50.0));
        assert_eq!(Breaker::default().state_at(123.0), BreakerState::Closed);
    }

    #[test]
    fn create_retries_are_bounded_and_stamp_a_reason() {
        let (mut cluster, mut vk, s) = setup();
        vk.policy.budget = 3;
        vk.policy.breaker_threshold = 100; // isolate the ladder
        // Fill all 8 podman slots with long jobs, then one more pod
        // that can never land.
        let mut lodged = Vec::new();
        for _ in 0..9 {
            let mut spec = offload_spec(1000.0);
            spec.resources.cpu_m = 500;
            spec.node_selector = Some("vk-podman".into());
            let p = cluster.create_pod(spec);
            s.schedule(&mut cluster, p, ScoringPolicy::Spread).unwrap();
            lodged.push(p);
        }
        let mut refused = None;
        for &p in &lodged {
            if vk.launch(&cluster, p, "podman", 0.0).is_err() {
                refused = Some(p);
            }
        }
        let victim = refused.expect("9th create refused");
        let mut terminal = Vec::new();
        let mut t = 0.0;
        while t < 120.0 {
            t += 5.0;
            terminal.extend(vk.reconcile(&mut cluster, t));
        }
        // Attempts 1 (launch), 2 (t=10), 3 (t=30) — budget reached.
        assert_eq!(terminal, vec![(victim, RemoteState::Failed)]);
        assert_eq!(vk.n_retry_exhausted, 1);
        assert_eq!(vk.retry_backlog(), 0);
        let p = cluster.pod(victim).unwrap();
        assert_eq!(p.phase, PodPhase::Failed);
        assert_eq!(
            p.failure_reason.as_deref(),
            Some("virtual node create retries exhausted")
        );
        cluster.check_accounting().unwrap();
    }

    #[test]
    fn an_unhealthy_site_trips_its_breaker_then_recovers() {
        let (mut cluster, mut vk, s) = setup();
        // 8 slot-filling jobs that run 60 s, then 3 more pods whose
        // consecutive create failures trip the breaker (threshold 3).
        let mut extra = Vec::new();
        for _ in 0..11 {
            let mut spec = offload_spec(60.0);
            spec.resources.cpu_m = 400;
            spec.node_selector = Some("vk-podman".into());
            let p = cluster.create_pod(spec);
            s.schedule(&mut cluster, p, ScoringPolicy::Spread).unwrap();
            if vk.launch(&cluster, p, "podman", 0.0).is_err() {
                extra.push(p);
            }
        }
        assert_eq!(extra.len(), 3);
        assert_eq!(vk.breaker("podman").state_at(0.1), BreakerState::Open);
        let mut t = 0.0;
        while t < 600.0 {
            t += 5.0;
            vk.reconcile(&mut cluster, t);
        }
        // The site itself was healthy (just full): a half-open probe
        // eventually lands, the breaker closes, everyone completes
        // within budget.
        assert_eq!(vk.breaker("podman").state_at(t), BreakerState::Closed);
        assert!(vk.n_breaker_refusals > 0, "open breaker fail-fasted");
        assert_eq!(vk.n_retry_exhausted, 0);
        for &p in &extra {
            assert_eq!(cluster.pod(p).unwrap().phase, PodPhase::Succeeded);
        }
    }

    #[test]
    fn running_per_site_census() {
        let (mut cluster, mut vk, s) = setup();
        for _ in 0..4 {
            let mut spec = offload_spec(500.0);
            spec.node_selector = Some("vk-podman".into());
            let p = cluster.create_pod(spec);
            s.schedule(&mut cluster, p, ScoringPolicy::Spread).unwrap();
            vk.launch(&cluster, p, "podman", 0.0).unwrap();
        }
        vk.reconcile(&mut cluster, 10.0);
        vk.reconcile(&mut cluster, 20.0);
        let census = vk.running_per_site();
        assert_eq!(census.get("podman"), Some(&4));
        assert_eq!(census.get("terabitpadova"), Some(&0));
    }
}
