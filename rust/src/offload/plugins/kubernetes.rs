//! Kubernetes plugin — the recas Tier-2 in Bari (`recas` in Fig. 2's
//! legend: "integrated, but not taking part to the test").
//!
//! §4: "Following a recent integration test, a Kubernetes plugin will be
//! brought to production soon." — i.e. the paper's announced extension,
//! implemented here as a first-class plugin: a remote k8s cluster with a
//! continuous scheduling loop and per-pod image pulls.

use crate::offload::sites::{SiteKind, SiteModel, SiteParams, SitePolicy};
use crate::util::bytes::GIB;

pub fn recas_tier2(seed: u64) -> SiteModel {
    SiteModel::new(
        "recas",
        SiteParams {
            kind: SiteKind::Kubernetes,
            slots: 400,
            submit_latency: 1.0,
            sched_interval: 5.0, // continuous-ish kube-scheduler loop
            queue_wait_median: 15.0,
            queue_wait_sigma: 0.5,
            startup_time: 25.0, // image pull on first use
            backfill_threshold: 0.0,
            failure_prob: 0.01,
            policy: SitePolicy { allow_fuse_mounts: true, allow_secrets: false },
            cpu_capacity_m: 400 * 1000,
            mem_capacity: 1600 * GIB,
        },
        seed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recas_profile() {
        let s = recas_tier2(0);
        assert_eq!(s.name, "recas");
        assert_eq!(s.params.kind, SiteKind::Kubernetes);
        assert!(s.params.sched_interval < 30.0, "k8s schedules continuously");
    }
}
