//! The four interLink plugins of §4, as site-calibrated constructors
//! over the [`super::sites::SiteModel`] engine.
//!
//! "At the time of writing, the AI_INFN platform is interfaced with
//! plugins accessing HTCondor, Slurm and Podman resources. Following a
//! recent integration test, a Kubernetes plugin will be brought to
//! production soon."

pub mod htcondor;
pub mod kubernetes;
pub mod podman;
pub mod slurm;

use super::sites::SiteModel;

/// The Figure-2 testbed: the four sites that took part in the
/// scalability test, plus recas (integrated but idle during the test).
pub fn fig2_testbed(seed: u64) -> Vec<SiteModel> {
    vec![
        htcondor::infn_tier1(seed ^ 1),
        slurm::leonardo(seed ^ 2),
        podman::cloud_vm(seed ^ 3),
        slurm::terabit_padova(seed ^ 4),
        kubernetes::recas_tier2(seed ^ 5),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn testbed_has_five_sites_with_fig2_labels() {
        let sites = fig2_testbed(1);
        let names: Vec<&str> =
            sites.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["infncnaf", "leonardo", "podman", "terabitpadova", "recas"]
        );
    }

    #[test]
    fn capacity_ordering_matches_site_classes() {
        let sites = fig2_testbed(1);
        let slot = |n: &str| {
            sites.iter().find(|s| s.name == n).unwrap().params.slots
        };
        // Supercomputer > Tier-1 > Tier-2 > single VM.
        assert!(slot("leonardo") > slot("infncnaf"));
        assert!(slot("infncnaf") > slot("recas"));
        assert!(slot("recas") > slot("podman"));
        assert!(slot("podman") <= 16);
    }
}
