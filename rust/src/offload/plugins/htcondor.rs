//! HTCondor plugin — the INFN-Tier-1 at CNAF (`infncnaf` in Fig. 2).
//!
//! HTCondor signature: the *negotiator* runs periodic matchmaking
//! cycles; submitted jobs sit idle until the next cycle matches them
//! against slots, then whole batches start together. A Tier-1 grants a
//! large, steady share to an opportunistic tenant but its fair-share
//! queue adds minutes of wait.

use crate::offload::sites::{SiteKind, SiteModel, SiteParams, SitePolicy};
use crate::util::bytes::GIB;

pub fn infn_tier1(seed: u64) -> SiteModel {
    SiteModel::new(
        "infncnaf",
        SiteParams {
            kind: SiteKind::HtCondor,
            slots: 1200,
            submit_latency: 4.0,
            sched_interval: 300.0, // negotiation cycle
            queue_wait_median: 180.0,
            queue_wait_sigma: 0.9,
            startup_time: 45.0, // apptainer image staging on the WN
            backfill_threshold: 0.0,
            failure_prob: 0.01,
            policy: SitePolicy {
                // Grid worker nodes: no user FUSE mounts, no shipped
                // secrets (§4's policy restrictions example).
                allow_fuse_mounts: false,
                allow_secrets: false,
            },
            cpu_capacity_m: 1200 * 1000,
            mem_capacity: 2400 * GIB,
        },
        seed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier1_profile() {
        let s = infn_tier1(0);
        assert_eq!(s.name, "infncnaf");
        assert_eq!(s.params.kind, SiteKind::HtCondor);
        assert!(s.params.sched_interval >= 60.0, "negotiator is periodic");
        assert!(!s.params.policy.allow_fuse_mounts);
    }
}
