//! Podman plugin — "a Virtual Machine in the Cloud provisioned via
//! Podman" (`podman` in Fig. 2).
//!
//! No batch system: the plugin talks straight to a container runtime on
//! one VM. Containers start in seconds; capacity is whatever the VM has
//! (here: 8 job slots). When full, create() refuses and the virtual-node
//! controller retries — there is no queue to hide in.

use crate::offload::sites::{SiteKind, SiteModel, SiteParams, SitePolicy};
use crate::util::bytes::GIB;

pub fn cloud_vm(seed: u64) -> SiteModel {
    SiteModel::new(
        "podman",
        SiteParams {
            kind: SiteKind::Podman,
            slots: 8,
            submit_latency: 0.3,
            sched_interval: 1.0,
            queue_wait_median: 0.0, // no queue
            queue_wait_sigma: 0.0,
            startup_time: 3.0, // image already cached on the VM
            backfill_threshold: 0.0,
            failure_prob: 0.005,
            policy: SitePolicy {
                // Our own VM: full control (§4 — the VM case is the
                // permissive end of the policy spectrum).
                allow_fuse_mounts: true,
                allow_secrets: true,
            },
            cpu_capacity_m: 8 * 1000,
            mem_capacity: 32 * GIB,
        },
        seed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn podman_is_tiny_and_instant() {
        let p = cloud_vm(0);
        assert_eq!(p.params.kind, SiteKind::Podman);
        assert!(p.params.slots <= 16);
        assert_eq!(p.params.queue_wait_median, 0.0);
        assert!(p.params.startup_time < 10.0);
        assert!(p.params.policy.allow_secrets);
    }
}
