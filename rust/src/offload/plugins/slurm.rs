//! Slurm plugins — CINECA Leonardo (`leonardo`) and the Terabit
//! HPC-Bubble in Padova (`terabitpadova`) of Fig. 2.
//!
//! Slurm signature: priority-ordered scheduling on a short interval with
//! *backfill* (short jobs slip into idle slots ahead of long waiters).
//! Leonardo is a busy pre-exascale machine: enormous capacity, long base
//! queue wait. The Terabit bubble is small and lightly loaded: short
//! waits, quick starts.

use crate::offload::sites::{SiteKind, SiteModel, SiteParams, SitePolicy};
use crate::util::bytes::GIB;

pub fn leonardo(seed: u64) -> SiteModel {
    SiteModel::new(
        "leonardo",
        SiteParams {
            kind: SiteKind::Slurm,
            slots: 4000,
            submit_latency: 2.0,
            sched_interval: 60.0,
            queue_wait_median: 900.0, // busy HPC queue
            queue_wait_sigma: 1.1,
            startup_time: 60.0, // singularity image + module env
            // Backfill windows on a busy pre-exascale machine are tight:
            // only near-trivial jobs slip through.
            backfill_threshold: 240.0,
            failure_prob: 0.02,
            policy: SitePolicy {
                // HPC login/compute policy allows the JuiceFS FUSE
                // client in user namespaces (§4's intermediate level),
                // but secrets stay home.
                allow_fuse_mounts: true,
                allow_secrets: false,
            },
            cpu_capacity_m: 4000 * 1000,
            mem_capacity: 16_000 * GIB,
        },
        seed,
    )
}

pub fn terabit_padova(seed: u64) -> SiteModel {
    SiteModel::new(
        "terabitpadova",
        SiteParams {
            kind: SiteKind::Slurm,
            slots: 256,
            submit_latency: 1.5,
            sched_interval: 30.0,
            queue_wait_median: 60.0, // dedicated bubble, short queue
            queue_wait_sigma: 0.6,
            startup_time: 20.0,
            backfill_threshold: 3600.0,
            failure_prob: 0.01,
            policy: SitePolicy { allow_fuse_mounts: true, allow_secrets: false },
            cpu_capacity_m: 256 * 1000,
            mem_capacity: 1024 * GIB,
        },
        seed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leonardo_is_big_and_slow_to_start() {
        let l = leonardo(0);
        let t = terabit_padova(0);
        assert!(l.params.slots > 10 * t.params.slots);
        assert!(l.params.queue_wait_median > 5.0 * t.params.queue_wait_median);
        assert_eq!(l.params.kind, SiteKind::Slurm);
        assert_eq!(t.params.kind, SiteKind::Slurm);
    }

    #[test]
    fn both_allow_juicefs_mounts() {
        assert!(leonardo(0).params.policy.allow_fuse_mounts);
        assert!(terabit_padova(0).params.policy.allow_fuse_mounts);
    }
}
