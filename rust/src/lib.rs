//! # ai-infn — reproduction of the AI_INFN federated-cloud ML platform
//!
//! Three-layer Rust + JAX + Pallas stack reproducing *"Supporting the
//! development of Machine Learning for fundamental science in a federated
//! Cloud with the AI_INFN platform"* (CS.DC 2025).
//!
//! Layer 3 (this crate) is the platform itself: a Kubernetes-like cluster
//! model carrying the paper's §2 hardware inventory, a JupyterHub-like
//! session hub ([`hub`]), the Kueue queueing/eviction controller
//! ([`kueue`]), the `vkd` submission microservice with Bunshin jobs
//! ([`vkd`]), and the Virtual-Kubelet / interLink offloading stack with
//! per-site plugins — HTCondor, Slurm, Podman, Kubernetes ([`offload`]).
//! Layers 2/1 are the JAX flash-simulation payload and its Pallas kernel,
//! AOT-lowered to HLO text and executed from [`runtime`] via PJRT —
//! Python never runs on the request path.
//!
//! See `DESIGN.md` for the module inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod util;
pub mod sim;
pub mod chaos;
pub mod cluster;
pub mod iam;
pub mod storage;
pub mod envs;
pub mod hub;
pub mod kueue;
pub mod vkd;
pub mod offload;
pub mod monitoring;
pub mod workload;
pub mod runtime;
pub mod coordinator;
pub mod experiments;
